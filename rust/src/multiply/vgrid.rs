//! Virtual-grid algebra for generalized Cannon on rectangular rank grids.
//!
//! Classic Cannon requires a square P̃ × P̃ grid. DBCSR runs on arbitrary
//! `pr × pc` grids (the paper's per-node rank counts produce e.g. 12 × 16);
//! the standard generalization folds a virtual `L × L` Cannon grid
//! (`L = lcm(pr, pc)`) onto the physical grid: virtual rank (i, j) lives at
//! physical (i mod pr, j mod pc), and each physical rank hosts
//! `(L/pr) · (L/pc)` virtual ranks ("slots"). Matrix block rows/cols are
//! cyclically assigned to the L virtual rows/cols — which nests exactly
//! inside the physical cyclic distribution, so no data conversion is
//! needed. For a square grid this reduces to textbook Cannon (one slot,
//! L = P̃).
//!
//! Per tick `s`, slot (i, j) multiplies A(i, g)·B(g, j) with
//! `g = (i + j + s) mod L`; A panels shift one physical column left and B
//! panels one row up between ticks. The **skew** phase moves A(i, g) from
//! its natural column (g mod pc) to ((g − i) mod L) mod pc, and B(g, j)
//! from row (g mod pr) to ((g − j) mod L) mod pr, both along one grid
//! dimension — exactly MPI_Cart-shifted Cannon pre-skewing.

/// Least common multiple.
pub fn lcm(a: usize, b: usize) -> usize {
    a / gcd(a, b) * b
}

pub fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// The virtual topology seen from one physical rank.
#[derive(Clone, Debug)]
pub struct VGrid {
    pub pr: usize,
    pub pc: usize,
    pub l: usize,
    /// This rank's physical coordinates.
    pub r: usize,
    pub c: usize,
}

impl VGrid {
    pub fn new(pr: usize, pc: usize, r: usize, c: usize) -> VGrid {
        VGrid::with_period(pr, pc, lcm(pr, pc), r, c)
    }

    /// A virtual grid with an explicit period `L` (any multiple of
    /// lcm(pr, pc) folds consistently onto the physical grid). The 2.5D
    /// driver uses periods divisible by the layer count so the `L`-tick
    /// sweep splits evenly into per-layer chunks.
    pub fn with_period(pr: usize, pc: usize, period: usize, r: usize, c: usize) -> VGrid {
        assert!(r < pr && c < pc);
        let base = lcm(pr, pc);
        assert!(
            period >= base && period % base == 0,
            "period {period} must be a positive multiple of lcm({pr}, {pc}) = {base}"
        );
        VGrid {
            pr,
            pc,
            l: period,
            r,
            c,
        }
    }

    /// Virtual rows hosted here (ascending).
    pub fn vrows(&self) -> Vec<usize> {
        (self.r..self.l).step_by(self.pr).collect()
    }

    /// Virtual cols hosted here (ascending).
    pub fn vcols(&self) -> Vec<usize> {
        (self.c..self.l).step_by(self.pc).collect()
    }

    /// Hosted slots (i, j), row-major over (vrows × vcols).
    pub fn slots(&self) -> Vec<(usize, usize)> {
        let vcols = self.vcols();
        self.vrows()
            .into_iter()
            .flat_map(|i| vcols.iter().map(move |&j| (i, j)))
            .collect()
    }

    /// K-group multiplied by slot (i, j) at tick `s`.
    pub fn group_at(&self, i: usize, j: usize, s: usize) -> usize {
        (i + j + s) % self.l
    }

    /// Physical column where A(i, g) starts after the skew.
    pub fn a_skew_col(&self, i: usize, g: usize) -> usize {
        self.a_skew_col_at(i, g, 0)
    }

    /// Physical row where B(g, j) starts after the skew.
    pub fn b_skew_row(&self, g: usize, j: usize) -> usize {
        self.b_skew_row_at(g, j, 0)
    }

    /// Physical column where A(i, g) must sit for the sweep to *start at
    /// tick `s0`* (the 2.5D per-layer offset): the slot (i, j) with
    /// (i + j + s0) ≡ g (mod L) lives in column ((g − i − s0) mod L) mod pc.
    pub fn a_skew_col_at(&self, i: usize, g: usize, s0: usize) -> usize {
        let l = self.l;
        ((g % l + 2 * l - i % l - s0 % l) % l) % self.pc
    }

    /// Physical row where B(g, j) must sit for a sweep starting at `s0`.
    pub fn b_skew_row_at(&self, g: usize, j: usize, s0: usize) -> usize {
        let l = self.l;
        ((g % l + 2 * l - j % l - s0 % l) % l) % self.pr
    }

    /// Initial (natural-distribution) A panels held here: (vrow, group).
    pub fn a_initial(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in self.vrows() {
            for g in (self.c..self.l).step_by(self.pc) {
                out.push((i, g));
            }
        }
        out
    }

    /// Initial B panels held here: (group, vcol).
    pub fn b_initial(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for g in (self.r..self.l).step_by(self.pr) {
            for j in self.vcols() {
                out.push((g, j));
            }
        }
        out
    }

    /// A panels this rank holds *after* the skew, sorted by (i, g):
    /// exactly one per slot, with g = group_at(i, j, 0).
    pub fn a_after_skew(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .slots()
            .into_iter()
            .map(|(i, j)| (i, self.group_at(i, j, 0)))
            .collect();
        v.sort_unstable();
        v
    }

    /// B panels after the skew, sorted by (g, j).
    pub fn b_after_skew(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .slots()
            .into_iter()
            .map(|(i, j)| (self.group_at(i, j, 0), j))
            .collect();
        v.sort_unstable();
        v
    }

    /// Global block ids of virtual row/col/group `x` out of `nblocks`.
    pub fn blocks_of(&self, x: usize, nblocks: usize) -> Vec<usize> {
        (x..nblocks).step_by(self.l).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lcm_gcd() {
        assert_eq!(gcd(12, 16), 4);
        assert_eq!(lcm(12, 16), 48);
        assert_eq!(lcm(4, 4), 4);
        assert_eq!(lcm(1, 5), 5);
    }

    #[test]
    fn square_grid_reduces_to_cannon() {
        let v = VGrid::new(3, 3, 1, 2);
        assert_eq!(v.l, 3);
        assert_eq!(v.slots(), vec![(1, 2)]);
        // tick s uses group (1+2+s) mod 3 — the textbook skew
        assert_eq!(v.group_at(1, 2, 0), 0);
        assert_eq!(v.group_at(1, 2, 1), 1);
    }

    #[test]
    fn slots_partition_virtual_grid() {
        let (pr, pc) = (2, 3);
        let l = lcm(pr, pc);
        let mut seen = vec![false; l * l];
        for r in 0..pr {
            for c in 0..pc {
                for (i, j) in VGrid::new(pr, pc, r, c).slots() {
                    assert!(!seen[i * l + j], "slot ({i},{j}) hosted twice");
                    seen[i * l + j] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "every virtual rank hosted");
    }

    #[test]
    fn every_slot_sees_every_group_exactly_once() {
        let v = VGrid::new(2, 3, 1, 2);
        for (i, j) in v.slots() {
            let mut groups: Vec<usize> = (0..v.l).map(|s| v.group_at(i, j, s)).collect();
            groups.sort_unstable();
            assert_eq!(groups, (0..v.l).collect::<Vec<_>>());
        }
    }

    #[test]
    fn skew_targets_are_where_ticks_expect() {
        // after skew, slot (i,j) must hold A(i, (i+j) mod L) — i.e. the
        // skew destination col of A(i, g) must host a slot (i, j) with
        // (i + j) ≡ g (mod L)
        for (pr, pc) in [(2usize, 2usize), (2, 3), (3, 2), (4, 6), (1, 4)] {
            let l = lcm(pr, pc);
            for i in 0..l {
                for g in 0..l {
                    let j = (g + l - i) % l; // the slot's vcol
                    let dest_col = j % pc;
                    let v = VGrid::new(pr, pc, i % pr, dest_col);
                    assert_eq!(v.a_skew_col(i, g), dest_col, "pr={pr} pc={pc} i={i} g={g}");
                    assert!(v.slots().contains(&(i, j)));
                    assert_eq!(v.group_at(i, j, 0), g);
                }
            }
        }
    }

    #[test]
    fn initial_panels_cover_all() {
        // union over ranks of a_initial == all (i, g) pairs
        let (pr, pc) = (2, 3);
        let l = lcm(pr, pc);
        let mut seen = vec![false; l * l];
        for r in 0..pr {
            for c in 0..pc {
                for (i, g) in VGrid::new(pr, pc, r, c).a_initial() {
                    assert!(!seen[i * l + g]);
                    seen[i * l + g] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn after_skew_multiset_is_consistent() {
        // globally, the post-skew panels are exactly {(i, g) : all pairs}
        let (pr, pc) = (4, 6);
        let l = lcm(pr, pc);
        let mut count = vec![0usize; l * l];
        for r in 0..pr {
            for c in 0..pc {
                for (i, g) in VGrid::new(pr, pc, r, c).a_after_skew() {
                    count[i * l + g] += 1;
                }
            }
        }
        assert!(count.iter().all(|&n| n == 1), "each A(i,g) exactly once");
    }

    #[test]
    fn with_period_slots_partition() {
        // a 2x2 grid folded at period 4 (the 2.5D c=4 case): every
        // virtual (i, j) hosted exactly once, 4 slots per rank
        let l = 4;
        let mut seen = vec![false; l * l];
        for r in 0..2 {
            for c in 0..2 {
                let v = VGrid::with_period(2, 2, l, r, c);
                assert_eq!(v.slots().len(), 4);
                for (i, j) in v.slots() {
                    assert!(!seen[i * l + j]);
                    seen[i * l + j] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn with_period_groups_cover() {
        let v = VGrid::with_period(2, 2, 4, 1, 0);
        for (i, j) in v.slots() {
            let mut groups: Vec<usize> = (0..v.l).map(|s| v.group_at(i, j, s)).collect();
            groups.sort_unstable();
            assert_eq!(groups, (0..v.l).collect::<Vec<_>>());
        }
    }

    #[test]
    #[should_panic(expected = "multiple of lcm")]
    fn with_period_rejects_bad_period() {
        let _ = VGrid::with_period(2, 3, 8, 0, 0);
    }

    #[test]
    fn offset_skew_targets_are_where_offset_ticks_expect() {
        // the layer-offset generalization of skew_targets_are_where_ticks
        // _expect: after an s0-offset skew, the slot (i, j) with
        // (i + j + s0) ≡ g must host A(i, g)
        for (pr, pc, period) in [(2usize, 2usize, 4usize), (2, 3, 6), (1, 4, 4), (2, 4, 8)] {
            let l = period;
            for s0 in 0..l {
                for i in 0..l {
                    for g in 0..l {
                        let j = (g + 2 * l - i - s0) % l;
                        let dest_col = j % pc;
                        let v = VGrid::with_period(pr, pc, period, i % pr, dest_col);
                        assert_eq!(
                            v.a_skew_col_at(i, g, s0),
                            dest_col,
                            "pr={pr} pc={pc} L={l} s0={s0} i={i} g={g}"
                        );
                        assert!(v.slots().contains(&(i, j)));
                        assert_eq!(v.group_at(i, j, s0), g);
                        // B mirror: slot (i, j) needs B(g, j) in row
                        // position b_skew_row_at(g, j, s0)
                        let vb = VGrid::with_period(pr, pc, period, (i) % pr, dest_col);
                        assert_eq!(vb.b_skew_row_at(g, j, s0), i % pr);
                    }
                }
            }
        }
    }

    #[test]
    fn zero_offset_matches_legacy_skew() {
        let v = VGrid::new(3, 4, 2, 1);
        for i in 0..v.l {
            for g in 0..v.l {
                assert_eq!(v.a_skew_col(i, g), v.a_skew_col_at(i, g, 0));
                assert_eq!(v.b_skew_row(g, i), v.b_skew_row_at(g, i, 0));
            }
        }
    }

    #[test]
    fn blocks_of_partitions() {
        let v = VGrid::new(2, 2, 0, 0);
        let mut all: Vec<usize> = (0..v.l).flat_map(|x| v.blocks_of(x, 10)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }
}
