//! Steady-state 2.5D pipelines: layer-resident operand handles that
//! amortize replication (and the pre-skew) across repeated multiplies.
//!
//! PR 3's planner quantified the problem this module solves: with the
//! one-time A/B layer replication charged to every call, `c = 1` always
//! wins at small rank counts and the 2.5D machinery never pays off end
//! to end. The 2.5D lineage paper (arXiv:1705.10218) runs the algorithm
//! inside iterative solvers where operands *stay* replicated across the
//! many multiplies of a solve and only the C reduce is paid per step —
//! this module is that steady state:
//!
//! * [`PipelineSession::admit`] takes a canonical layer-cyclic
//!   [`DistMatrix`] onto the session's [`Grid3D`] **once**: one
//!   [`replicate_to_layers`] broadcast plus one skew exchange per
//!   requested side, landing the operand in the driver's **native**
//!   tick-`s0` layout. Both costs are booked in the `repl_` bucket of
//!   the session's [`MultiplyStats`] — never on a multiply.
//! * [`PipelineSession::multiply_resident`] then serves unlimited
//!   multiplies that extract panels locally (no replication, no skew)
//!   and pay only the shortened shift sweep plus the per-call
//!   cross-layer C reduce. Its per-call stats carry `repl_bytes = 0` by
//!   construction — the observable amortization.
//!
//! An operand's native layout is **side-specific** (A panels `(i, g)`
//! skew along grid rows, B panels `(g, j)` along grid columns), so a
//! handle carries up to two shares ([`Sides`]). Elementwise updates
//! (scale, axpy) apply to every share identically, which keeps the
//! layer replicas bit-identical — that is what lets `linalg`'s Newton
//! iterations derive next-step operands without ever re-entering the
//! skew path for constant matrices.

use std::collections::{BTreeMap, BTreeSet};

use crate::backend::gpu_sim::DeviceOom;
use crate::dist::{CommView, Grid3D, Payload, RmaWindow, Transport};
use crate::matrix::{BlockLayout, DistMatrix, Distribution, LocalCsr, Mode};
use crate::obs::{Lane, Phase};
use crate::util::stats::{MultiplyStats, PlanSummary};

use super::cannon::{
    exchange, extract_panel, panel_meta, rma_exchange_finish, rma_exchange_start, Key,
};
use super::engine::LocalEngine;
use super::recovery::{self, RecoveryPlan};
use super::sparse_exchange::{
    assemble_c_from_layouts, decode_framed_share, encode_framed_share, reduce_c_finish,
    reduce_c_start, CPattern, PendingReduce,
};
use super::twofive::{
    a_skew_plan, a_start_keys, b_skew_plan, b_start_keys, layer_ticks, multiply_twofive_ft,
    replicate_to_layers, sweep_period, twofive_sweep, SweepOutcome, SweepState,
};
use super::vgrid::VGrid;
use super::{planner, MultiplyConfig, MultiplyOutcome};

// Residency pre-skew and spare-adoption tags / RMA window ids, from the
// central registry (`dist::tags` holds the non-collision assertions).
use crate::dist::tags::{
    TAG_RES_SKEW_A, TAG_RES_SKEW_B, TAG_SPARE_ADOPT, WIN_ADOPT_A, WIN_ADOPT_B, WIN_RES_SKEW_A,
    WIN_RES_SKEW_B,
};

/// Which native shares an admitted operand carries. The A and B layouts
/// differ (module docs), so admit only what the workload multiplies on:
/// a pure `A·B` pipeline admits `A`/`B`; an iterate that appears on both
/// sides of a Newton recurrence needs `Both`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sides {
    A,
    B,
    Both,
}

impl Sides {
    fn wants_a(self) -> bool {
        matches!(self, Sides::A | Sides::Both)
    }
    fn wants_b(self) -> bool {
        matches!(self, Sides::B | Sides::Both)
    }
}

/// A layer-resident operand: replicated across the session's layers and
/// pre-skewed into the native tick-`s0` layout, ready to multiply with
/// zero setup traffic. Obtained from [`PipelineSession::admit`] /
/// [`PipelineSession::adopt`]; the replication cost was charged there,
/// once.
#[derive(Clone)]
pub struct ResidentOperand {
    a_share: Option<DistMatrix>,
    b_share: Option<DistMatrix>,
}

impl ResidentOperand {
    pub(crate) fn from_shares(
        a_share: Option<DistMatrix>,
        b_share: Option<DistMatrix>,
    ) -> ResidentOperand {
        assert!(
            a_share.is_some() || b_share.is_some(),
            "a resident operand needs at least one native share"
        );
        ResidentOperand { a_share, b_share }
    }

    pub fn a_share(&self) -> Option<&DistMatrix> {
        self.a_share.as_ref()
    }

    pub fn b_share(&self) -> Option<&DistMatrix> {
        self.b_share.as_ref()
    }

    /// Any present share (A preferred). Within one layer the share's
    /// ranks collectively cover the global matrix exactly once, so
    /// layer-scoped reductions (trace, Frobenius) over it are exact;
    /// across layers it is replicated `c`-fold.
    pub fn share(&self) -> &DistMatrix {
        self.a_share
            .as_ref()
            .or(self.b_share.as_ref())
            .expect("resident operand holds a share")
    }

    pub fn mode(&self) -> Mode {
        self.share().mode
    }

    /// Global block layouts (rows, cols) of the logical matrix.
    pub fn layouts(&self) -> (BlockLayout, BlockLayout) {
        let s = self.share();
        (s.rows.clone(), s.cols.clone())
    }

    /// In-place scalar multiply, applied to every share. Uniform across
    /// layers (each layer transforms identical replica data), so
    /// residency is preserved for free.
    pub fn scale(&mut self, alpha: f32) {
        if let Some(m) = self.a_share.as_mut() {
            m.scale(alpha);
        }
        if let Some(m) = self.b_share.as_mut() {
            m.scale(alpha);
        }
    }

    /// `self += alpha · other`, share by share. The two operands must
    /// have been admitted with the same sides on the same session (their
    /// native patterns then match exactly).
    pub fn add_scaled(&mut self, other: &ResidentOperand, alpha: f32) {
        assert_eq!(
            self.a_share.is_some(),
            other.a_share.is_some(),
            "axpy operands must carry the same shares"
        );
        assert_eq!(
            self.b_share.is_some(),
            other.b_share.is_some(),
            "axpy operands must carry the same shares"
        );
        if let (Some(d), Some(s)) = (self.a_share.as_mut(), other.a_share.as_ref()) {
            d.add_scaled(s, alpha);
        }
        if let (Some(d), Some(s)) = (self.b_share.as_mut(), other.b_share.as_ref()) {
            d.add_scaled(s, alpha);
        }
    }
}

/// One rank's handle on a steady-state 2.5D pipeline over a fixed
/// [`Grid3D`]. Collective: every rank of the topology constructs the
/// session and calls its methods at the same logical points (they wrap
/// the collective replicate/skew/multiply primitives).
pub struct PipelineSession {
    g3: Grid3D,
    cfg: MultiplyConfig,
    stats: MultiplyStats,
    multiplies: u64,
    /// A [`Self::multiply_resident_pipelined`] call whose cross-layer C
    /// reduce is still in flight — drained (overlapped) behind the next
    /// call's sweep, or at [`Self::flush_pipeline`].
    pending: Option<PendingCall>,
}

/// Everything needed to finish a deferred resident multiply once its
/// C reduce drains: the partial panels the drain merges into, the open
/// reduce, the C frame layouts (the operand handles may be gone by
/// then), and the call's stats-so-far.
struct PendingCall {
    out_panels: Vec<LocalCsr>,
    c_pats: Vec<CPattern>,
    reduce: PendingReduce,
    c_rows: BlockLayout,
    c_cols: BlockLayout,
    mode: Mode,
    stats: MultiplyStats,
    sweep_seconds: f64,
}

impl PipelineSession {
    /// Wrap a topology and a multiply configuration. `cfg.algorithm` is
    /// ignored — the session always runs the 2.5D driver on its own
    /// grid (`layers = 1` degenerates to a skew-resident Cannon whose
    /// pre-skew is still amortized).
    pub fn new(g3: Grid3D, cfg: MultiplyConfig) -> PipelineSession {
        PipelineSession {
            g3,
            cfg,
            stats: MultiplyStats::default(),
            multiplies: 0,
            pending: None,
        }
    }

    pub fn grid(&self) -> &Grid3D {
        &self.g3
    }

    pub fn config(&self) -> &MultiplyConfig {
        &self.cfg
    }

    /// Cumulative counters over the session's lifetime: every admit's
    /// `repl_` bucket plus every resident multiply's per-call stats.
    pub fn stats(&self) -> &MultiplyStats {
        &self.stats
    }

    /// Resident multiplies served so far.
    pub fn multiplies(&self) -> u64 {
        self.multiplies
    }

    /// One-time bytes spent making operands resident (replication
    /// broadcasts + pre-skew exchanges) — the `repl_` bucket.
    pub fn repl_bytes(&self) -> u64 {
        self.stats.repl_bytes
    }

    /// Virtual seconds of the same one-time setup (max-style per-rank
    /// accounting happens at the caller; this is this rank's own span).
    pub fn repl_seconds(&self) -> f64 {
        self.stats.repl_s
    }

    /// Take a canonical layer-cyclic matrix resident: replicate across
    /// layers (a no-op at `layers = 1`) and pre-skew into the native
    /// layout of the requested `sides`. Charged once, to the `repl_`
    /// bucket. Layers > 0 may pass a zero-filled share — the broadcast
    /// delivers layer 0's elements, exactly like [`replicate_to_layers`].
    pub fn admit(&mut self, m: DistMatrix, sides: Sides) -> ResidentOperand {
        let t0 = self.g3.world.now();
        let b0 = self.g3.world.stats().bytes_sent;
        let mut m = m;
        replicate_to_layers(&self.g3, &mut m, self.cfg.transport);
        let (a_share, b_share) = self.build_shares(
            sides.wants_a().then_some(&m),
            sides.wants_b().then_some(&m),
        );
        self.book_setup(t0, b0);
        if self.cfg.verify {
            self.g3.world.phase_mark();
        }
        ResidentOperand::from_shares(a_share, b_share)
    }

    /// Admit an A-side operand and a B-side operand together (the `A·B`
    /// pipeline shape): both replications issue back to back and, under
    /// the one-sided transport, the two skew exchanges overlap on the
    /// wire exactly like the in-driver canonical skew — this is the
    /// setup the steady-state planner prices.
    pub fn admit_pair(
        &mut self,
        a: DistMatrix,
        b: DistMatrix,
    ) -> (ResidentOperand, ResidentOperand) {
        let t0 = self.g3.world.now();
        let b0 = self.g3.world.stats().bytes_sent;
        let (mut a, mut b) = (a, b);
        replicate_to_layers(&self.g3, &mut a, self.cfg.transport);
        replicate_to_layers(&self.g3, &mut b, self.cfg.transport);
        let (a_share, b_share) = self.build_shares(Some(&a), Some(&b));
        self.book_setup(t0, b0);
        if self.cfg.verify {
            self.g3.world.phase_mark();
        }
        (
            ResidentOperand::from_shares(a_share, None),
            ResidentOperand::from_shares(None, b_share),
        )
    }

    /// Make an **already layer-replicated** matrix resident without the
    /// broadcast — for matrices every layer constructed bit-identically
    /// in place (identities, elementwise derivations, deterministic
    /// per-layer collectives like a transpose). Only the pre-skew
    /// traffic is charged (still to the `repl_` bucket). Passing a
    /// matrix whose layer shares differ produces a wrong C; the
    /// driver's replica fingerprint check does not cover native-layout
    /// shares, so this is the caller's contract.
    pub fn adopt(&mut self, m: &DistMatrix, sides: Sides) -> ResidentOperand {
        let t0 = self.g3.world.now();
        let b0 = self.g3.world.stats().bytes_sent;
        let (a_share, b_share) = self.build_shares(
            sides.wants_a().then_some(m),
            sides.wants_b().then_some(m),
        );
        self.book_setup(t0, b0);
        if self.cfg.verify {
            self.g3.world.phase_mark();
        }
        ResidentOperand::from_shares(a_share, b_share)
    }

    /// Multiply `C = A · B` on already-resident operands: the shortened
    /// skew-free sweep plus the per-call cross-layer C reduce — nothing
    /// else. Returns the same [`MultiplyOutcome`] as `multiply()`; its
    /// stats carry `repl_bytes = 0` (the amortization this session
    /// exists for) and a plan record with `source = "resident"` and
    /// `charged_replication = false`. Layer 0 holds the reduced C in
    /// the layer grid's cyclic distribution; other layers return a zero
    /// share (see [`multiply_twofive`]).
    pub fn multiply_resident(
        &mut self,
        a: &ResidentOperand,
        b: &ResidentOperand,
    ) -> Result<MultiplyOutcome, DeviceOom> {
        assert!(
            self.pending.is_none(),
            "a pipelined multiply's reduce is still in flight — call \
             flush_pipeline() before switching to synchronous resident calls"
        );
        let am = a
            .a_share
            .as_ref()
            .expect("left operand needs an A-side share (admit with Sides::A or Both)");
        let bm = b
            .b_share
            .as_ref()
            .expect("right operand needs a B-side share (admit with Sides::B or Both)");
        let world = self.g3.world.clone();
        let plan = self.resident_plan(am, bm);
        if self.cfg.plan_verbose && world.rank() == 0 {
            println!(
                "[plan] {} {}x{}x{} (source {}, replication amortized): \
                 predicted {:.3}ms total, {:.3}ms comm",
                plan.algorithm,
                plan.rows,
                plan.cols,
                plan.layers,
                plan.source,
                plan.predicted_seconds * 1e3,
                plan.predicted_comm_s * 1e3,
            );
        }
        let mut engine = LocalEngine::new(
            self.cfg.engine.clone(),
            am.mode,
            self.cfg.perf.clone(),
            self.cfg.runtime.clone(),
            self.cfg.gpu_share,
        );
        let t0 = world.now();
        let comm0 = world.stats();
        // Faults fire once, on the session's first resident multiply;
        // later calls carry the same ranks as already-dead so survivors
        // keep routing around them (native shares are exactly what the
        // resident recovery path requires).
        let fault_plan = if self.cfg.faults.is_empty() {
            RecoveryPlan::default()
        } else {
            assert!(
                self.g3.layers > 1,
                "Unrecoverable: fault injection on a session with layers = 1 — \
                 no replica layer to recover from (run with c > 1)"
            );
            if self.multiplies == 0 {
                RecoveryPlan {
                    kill_now: self.cfg.faults.clone(),
                    already_dead: Vec::new(),
                }
            } else {
                RecoveryPlan {
                    kill_now: Vec::new(),
                    already_dead: self.cfg.faults.iter().map(|f| f.rank).collect(),
                }
            }
        };
        let (mut c, holds) = multiply_twofive_ft(
            &self.g3,
            am,
            bm,
            &mut engine,
            self.cfg.transport,
            self.cfg.overlap,
            &fault_plan,
        )?;
        // on-the-fly filtering, after the cross-layer reduce — identical
        // semantics to the one-shot `multiply()` path (the holding layer
        // has the reduced result; other layers' zero shells must not be
        // counted)
        let filtered = if holds {
            c.filter_blocks(self.cfg.filter_eps)
        } else {
            0
        };
        let comm1 = world.stats();
        let mut stats = engine.stats.clone();
        stats.comm_bytes = comm1.bytes_sent - comm0.bytes_sent;
        stats.comm_msgs = comm1.msgs_sent - comm0.msgs_sent;
        // monotone counter, but clamp: a negative delta would poison the
        // session's cumulative sums silently
        stats.comm_wait_s = (comm1.wait_seconds - comm0.wait_seconds).max(0.0);
        stats.meta_bytes = comm1.meta_bytes - comm0.meta_bytes;
        stats.retrans_bytes = comm1.retrans_bytes - comm0.retrans_bytes;
        stats.retrans_s = (comm1.retrans_s - comm0.retrans_s).max(0.0);
        stats.plan = Some(plan);
        // an active recovery plan forces every shift synchronous (the
        // double-buffered rings cannot heal mid-flight) — surface the
        // downgrade instead of letting `overlap` silently lie
        if self.cfg.overlap && fault_plan.active() {
            if world.rank() == 0 && !self.stats.overlap_downgraded {
                println!(
                    "[notice] overlap requested but fault injection forces \
                     synchronous shifts — comm/compute overlap is disabled \
                     while the session carries faults"
                );
            }
            stats.overlap_downgraded = true;
        }
        super::book_sparse_stats(&mut stats, am, bm, &c, filtered, holds);
        self.multiplies += 1;
        self.stats.merge(&stats);
        if self.cfg.verify {
            world.phase_mark();
        }
        Ok(MultiplyOutcome {
            c,
            stats,
            virtual_seconds: world.now() - t0,
        })
    }

    /// [`Self::multiply_resident`] with the cross-layer C reduce
    /// overlapped across calls: each invocation runs its own sweep
    /// first, *then* drains the previous call's reduce — by which point
    /// this rank's clock has advanced through a sweep's worth of
    /// compute, so the contributions (issued before that sweep began)
    /// are old arrivals and the drain books little or no wait. The
    /// hidden transfer time is credited to the previous call's
    /// [`MultiplyStats::overlap_hidden_s`].
    ///
    /// Returns the **previous** call's outcome (`None` on the first
    /// call); [`Self::flush_pipeline`] returns the last one. C is
    /// bit-identical to the synchronous path — deferral cannot reorder
    /// the reduce's arrivals (FIFO per source/tag) and the merge order
    /// is unchanged. Fault injection is not supported here (a deferred
    /// reduce cannot heal layers that die between calls); under
    /// `cfg.verify` the quiescence mark moves to the flush, since a
    /// pipelined call is deliberately *not* quiescent.
    pub fn multiply_resident_pipelined(
        &mut self,
        a: &ResidentOperand,
        b: &ResidentOperand,
    ) -> Result<Option<MultiplyOutcome>, DeviceOom> {
        assert!(
            self.cfg.faults.is_empty(),
            "pipelined resident multiplies do not support fault injection; \
             use multiply_resident"
        );
        let am = a
            .a_share
            .as_ref()
            .expect("left operand needs an A-side share (admit with Sides::A or Both)");
        let bm = b
            .b_share
            .as_ref()
            .expect("right operand needs a B-side share (admit with Sides::B or Both)");
        let world = self.g3.world.clone();
        let plan = self.resident_plan(am, bm);
        let mut engine = LocalEngine::new(
            self.cfg.engine.clone(),
            am.mode,
            self.cfg.perf.clone(),
            self.cfg.runtime.clone(),
            self.cfg.gpu_share,
        );
        let t0 = world.now();
        let comm0 = world.stats();
        let state = match twofive_sweep(
            &self.g3,
            am,
            bm,
            &mut engine,
            self.cfg.transport,
            self.cfg.overlap,
            &RecoveryPlan::default(),
        )? {
            SweepOutcome::Live(state) => state,
            SweepOutcome::Dead(_) => unreachable!("no fault plan, nobody dies"),
        };
        // the sweep advanced this rank's clock through its compute; the
        // previous call's reduce contributions were issued before that
        // sweep began, so draining them *now* is the overlap. The drain's
        // span and wait belong to the *previous* call (finish_pending
        // books them there) — subtract both from this call's window so
        // nothing is counted twice
        let drain_t0 = world.now();
        let drain_w0 = world.stats().wait_seconds;
        let prev = self.finish_pending();
        let drain_span = world.now() - drain_t0;
        let drain_wait = world.stats().wait_seconds - drain_w0;
        let SweepState {
            mut out_panels,
            mut c_pats,
            ctx,
        } = state;
        debug_assert!(ctx.is_none(), "no fault plan arms no recovery");
        let reduce = reduce_c_start(
            &self.g3,
            self.cfg.transport,
            &mut out_panels,
            &mut c_pats,
            am.mode,
        );
        let comm1 = world.stats();
        let mut stats = engine.stats.clone();
        stats.comm_bytes = comm1.bytes_sent - comm0.bytes_sent;
        stats.comm_msgs = comm1.msgs_sent - comm0.msgs_sent;
        stats.comm_wait_s = (comm1.wait_seconds - comm0.wait_seconds - drain_wait).max(0.0);
        stats.meta_bytes = comm1.meta_bytes - comm0.meta_bytes;
        stats.retrans_bytes = comm1.retrans_bytes - comm0.retrans_bytes;
        stats.retrans_s = (comm1.retrans_s - comm0.retrans_s).max(0.0);
        stats.plan = Some(plan);
        self.pending = Some(PendingCall {
            out_panels,
            c_pats,
            reduce,
            c_rows: am.rows.clone(),
            c_cols: bm.cols.clone(),
            mode: am.mode,
            stats,
            sweep_seconds: world.now() - t0 - drain_span,
        });
        self.multiplies += 1;
        Ok(prev)
    }

    /// Drain the in-flight reduce of the last pipelined call and return
    /// its outcome (`None` if nothing is pending). Collective whenever
    /// any rank has a pending call. Stamps the deferred quiescence mark
    /// under `cfg.verify`.
    pub fn flush_pipeline(&mut self) -> Option<MultiplyOutcome> {
        let out = self.finish_pending();
        if out.is_some() && self.cfg.verify {
            self.g3.world.phase_mark();
        }
        out
    }

    /// Complete a deferred call: drain its reduce (booking unhidden
    /// wait to the call and the hidden remainder to
    /// `overlap_hidden_s`), filter, assemble its C, and fold the stats
    /// into the session totals.
    fn finish_pending(&mut self) -> Option<MultiplyOutcome> {
        let PendingCall {
            mut out_panels,
            mut c_pats,
            reduce,
            c_rows,
            c_cols,
            mode,
            mut stats,
            sweep_seconds,
        } = self.pending.take()?;
        let world = &self.g3.world;
        let t0 = world.now();
        let wait0 = world.stats().wait_seconds;
        let modeled = reduce_c_finish(
            &self.g3.layer_comm,
            reduce,
            &mut out_panels,
            &mut c_pats,
            mode,
        );
        let wait_delta = (world.stats().wait_seconds - wait0).max(0.0);
        stats.comm_wait_s += wait_delta;
        stats.overlap_hidden_s += (modeled - wait_delta).max(0.0);
        world.prof_span(Lane::Driver, Phase::Drain, None, t0, world.now(), 0, None);
        let holds = self.g3.layer == 0;
        let mut c = assemble_c_from_layouts(
            &c_rows,
            &c_cols,
            (self.g3.rows, self.g3.cols),
            self.g3.grid.coords(),
            mode,
            &out_panels,
            &c_pats,
            holds,
        );
        let filtered = if holds {
            c.filter_blocks(self.cfg.filter_eps)
        } else {
            0
        };
        stats.filtered_blocks += filtered;
        // operand occupancies were not stashed (the handles may be
        // gone); book the result side, which is what filtering reports
        if holds {
            stats.c_nnz_blocks += c.local.nnz() as u64;
            stats.c_total_blocks += (c.local.nrows() * c.local.ncols()) as u64;
        }
        let virtual_seconds = sweep_seconds + (world.now() - t0);
        self.stats.merge(&stats);
        Some(MultiplyOutcome {
            c,
            stats,
            virtual_seconds,
        })
    }

    /// The executed-plan record of one resident call: the session's
    /// fixed topology priced with replication amortized away.
    fn resident_plan(&self, am: &DistMatrix, bm: &DistMatrix) -> PlanSummary {
        let input = planner::PlanInput {
            p: self.g3.world.size(),
            m: am.rows.dim,
            n: bm.cols.dim,
            k: am.cols.dim,
            block: am.rows.block,
            elem_bytes: planner::elem_bytes_for(am.mode),
            net: self.g3.world.net(),
            perf: self.cfg.perf.clone(),
            transport: self.cfg.transport,
            gpu_share: self.cfg.gpu_share,
            threads: self.cfg.engine.threads.max(1),
            charge_replication: false,
            horizon: 1,
            overlap: self.cfg.overlap,
            occ_a: am.local_occupancy(),
            occ_b: bm.local_occupancy(),
            failure_rate: 0.0,
            recovery: planner::RecoveryModel::default(),
            spares: 0,
        };
        let cand =
            planner::predict_grid(&input, self.g3.rows, self.g3.cols, self.g3.layers);
        // a horizon-1 uncharged prediction still includes the in-run
        // skew (the planner cannot tell a resident one-shot from a
        // canonical one); operands here are pre-skewed, so drop that
        // term explicitly — what remains is shift + reduce + compute,
        // exactly this call's cost structure
        PlanSummary {
            algorithm: "2.5d".to_string(),
            rows: self.g3.rows,
            cols: self.g3.cols,
            layers: self.g3.layers,
            source: "resident",
            charged_replication: false,
            horizon: 1,
            predicted_seconds: cand.cost.total_s - cand.cost.skew_s,
            predicted_comm_s: cand.cost.comm_s() - cand.cost.skew_s,
        }
    }

    fn book_setup(&mut self, t0: f64, b0: u64) {
        let world = &self.g3.world;
        let bytes = world.stats().bytes_sent - b0;
        self.stats.repl_s += world.now() - t0;
        self.stats.repl_bytes += bytes;
        // span bounds equal the booked delta exactly, so the driver
        // lane reconciles with the `repl_` bucket
        world.prof_span(Lane::Driver, Phase::Replicate, None, t0, world.now(), bytes, None);
    }

    /// Run the A-side skew of `a_src` and the B-side skew of `b_src`
    /// from the canonical layout to this layer's tick-`s0` native
    /// positions, assembling the received panels into native-layout
    /// matrices. Under the one-sided transport both exchanges' puts
    /// issue before either epoch closes (they overlap on the wire);
    /// two-sided serializes them, mirroring the in-driver skew.
    fn build_shares(
        &self,
        a_src: Option<&DistMatrix>,
        b_src: Option<&DistMatrix>,
    ) -> (Option<DistMatrix>, Option<DistMatrix>) {
        let g3 = &self.g3;
        let grid = &g3.grid;
        let (r, c) = grid.coords();
        let lv = sweep_period(g3.rows, g3.cols, g3.layers);
        let vg = VGrid::with_period(g3.rows, g3.cols, lv, r, c);
        let (s0, _) = layer_ticks(lv, g3.layers, g3.layer);
        let slots = vg.slots();

        // the same routing the driver's canonical skew uses — the
        // shared helpers guarantee admitted shares land exactly at the
        // driver's native tick-s0 positions
        let a_route = a_src.map(|m| {
            let keys = a_start_keys(&vg, &slots, s0);
            let (held, sends, recvs) = a_skew_plan(m, &vg, s0, &keys);
            (m, held, sends, recvs)
        });
        let b_route = b_src.map(|m| {
            let keys = b_start_keys(&vg, &slots, s0);
            let (held, sends, recvs) = b_skew_plan(m, &vg, s0, &keys);
            (m, held, sends, recvs)
        });

        let (a_panels, b_panels) = match self.cfg.transport {
            Transport::TwoSided => {
                let ap = a_route.map(|(m, held, sends, recvs)| {
                    let panels = exchange(
                        &grid.row,
                        held,
                        &sends,
                        &recvs,
                        |key| panel_meta(m, &vg, key.0, key.1),
                        TAG_RES_SKEW_A,
                        m.mode,
                    );
                    (m, panels)
                });
                let bp = b_route.map(|(m, held, sends, recvs)| {
                    let panels = exchange(
                        &grid.col,
                        held,
                        &sends,
                        &recvs,
                        |key| panel_meta(m, &vg, key.0, key.1),
                        TAG_RES_SKEW_B,
                        m.mode,
                    );
                    (m, panels)
                });
                (ap, bp)
            }
            // the get transport's pull semantics cover only the per-tick
            // ring shifts; the pre-skew reuses the put path
            Transport::OneSided | Transport::OneSidedGet => {
                let ex_a = a_route.map(|(m, held, sends, recvs)| {
                    (
                        m,
                        rma_exchange_start(&grid.row, WIN_RES_SKEW_A, held, &sends, &recvs, m.mode),
                    )
                });
                let ex_b = b_route.map(|(m, held, sends, recvs)| {
                    (
                        m,
                        rma_exchange_start(&grid.col, WIN_RES_SKEW_B, held, &sends, &recvs, m.mode),
                    )
                });
                let ap = ex_a.map(|(m, ex)| {
                    (
                        m,
                        rma_exchange_finish(ex, |key| panel_meta(m, &vg, key.0, key.1), m.mode),
                    )
                });
                let bp = ex_b.map(|(m, ex)| {
                    (
                        m,
                        rma_exchange_finish(ex, |key| panel_meta(m, &vg, key.0, key.1), m.mode),
                    )
                });
                (ap, bp)
            }
        };
        (
            a_panels.map(|(m, panels)| assemble_native(g3, &m.rows, &m.cols, &panels, m.mode)),
            b_panels.map(|(m, panels)| assemble_native(g3, &m.rows, &m.cols, &panels, m.mode)),
        )
    }

    /// Splice parked hot spares into the grid seats of dead ranks, so
    /// the *next* resident multiply runs at full width with a zero
    /// recovery bill. Collective over the session's surviving compute
    /// ranks, paired with [`spare_serve`] on every spare; `run_world`
    /// must be the full world `run_ranks_opts` handed the rank closure
    /// (compute ranks `0..P`, spares `P..P+S`).
    ///
    /// The protocol is agreement-free: every participant derives the
    /// same (dead, spare) pairing and the same coordinator from the
    /// shared fault plan ([`recovery::adoption_pairs`] /
    /// [`recovery::adoption_coordinator`]). The coordinator sends each
    /// adopted spare a directive on `TAG_SPARE_ADOPT` — the one channel
    /// allowed to cross quiescence epochs — and releases the rest.
    /// Survivors then expose their native shares of `a` and `b` on the
    /// fresh `WIN_ADOPT_A`/`WIN_ADOPT_B` windows over the remapped
    /// full-width world; the spares pull what the dead rank held
    /// (get-only, origin-charged), and a recovery fence orders every
    /// fetch before the exposures are retired. Dead ranks beyond the
    /// spare pool stay in the fault list — later multiplies keep
    /// routing around them.
    ///
    /// Must run after the faulted multiply (the spares derive roles
    /// from the same plan, so adoption before anyone died would
    /// desynchronize the two sides); with an empty fault list it only
    /// releases the spares. Call it exactly once per session that was
    /// started with `RunOpts::spares > 0` — a parked spare blocks until
    /// its directive arrives.
    pub fn adopt_spares(
        &mut self,
        run_world: &CommView,
        a: &ResidentOperand,
        b: &ResidentOperand,
    ) -> AdoptionReport {
        let compute = self.g3.rows * self.g3.cols * self.g3.layers;
        let spares = run_world.size() - compute;
        assert!(
            self.cfg.faults.is_empty() || self.multiplies > 0,
            "adopt_spares before the faulted multiply: nobody has died yet, and \
             the spares derive their roles from the same fault plan"
        );
        let pairs = recovery::adoption_pairs(&self.cfg.faults, compute, spares);
        let released: Vec<usize> = (compute + pairs.len()..compute + spares).collect();
        let coord = recovery::adoption_coordinator(&self.cfg.faults, compute);
        // a dead seat takes no part in its own replacement — the pairing
        // is deterministic, so report it without touching the wire (the
        // caller must not drive this session again; its seat now belongs
        // to a spare)
        if run_world.killed() {
            return AdoptionReport {
                adopted: pairs,
                released,
                bytes: 0,
                seconds: 0.0,
            };
        }
        let t0 = run_world.now();
        if run_world.rank() == coord {
            for &(dead, spare) in &pairs {
                run_world.send(
                    spare,
                    TAG_SPARE_ADOPT,
                    Payload::F32(vec![
                        dead as f32,
                        run_world.phases() as f32,
                        self.multiplies as f32,
                    ]),
                );
            }
            for &spare in &released {
                run_world.send(spare, TAG_SPARE_ADOPT, Payload::Empty);
            }
        }
        if pairs.is_empty() {
            return AdoptionReport {
                adopted: pairs,
                released,
                bytes: 0,
                seconds: run_world.now() - t0,
            };
        }
        let b0 = run_world.stats();
        let members = remap_members(compute, &pairs);
        let g3 = Grid3D::new(
            run_world.subview(&members),
            self.g3.rows,
            self.g3.cols,
            self.g3.layers,
        );
        // serve the replica fetches: fresh window ids keep every
        // participant on window instance 1, so the verifier's
        // cross-instance get check stays exact
        let mut win_a = RmaWindow::new(&g3.world, WIN_ADOPT_A);
        let mut win_b = RmaWindow::new(&g3.world, WIN_ADOPT_B);
        win_a.expose(encode_framed_share(a.a_share.as_ref().expect(
            "adoption serves the A·B pipeline shape: left operand carries the A share",
        )));
        win_b.expose(encode_framed_share(b.b_share.as_ref().expect(
            "adoption serves the A·B pipeline shape: right operand carries the B share",
        )));
        let leftover: Vec<usize> = self
            .cfg
            .faults
            .iter()
            .map(|f| f.rank)
            .filter(|d| !pairs.iter().any(|(pd, _)| pd == d))
            .collect();
        recovery::survivor_fence(
            &g3.world,
            &RecoveryPlan {
                kill_now: Vec::new(),
                already_dead: leftover.clone(),
            },
        );
        win_a.close_epoch(&[]);
        win_b.close_epoch(&[]);
        let b1 = run_world.stats();
        let bytes = (b1.bytes_sent - b0.bytes_sent) + (b1.meta_bytes - b0.meta_bytes);
        let seconds = run_world.now() - t0;
        self.g3 = g3;
        self.cfg
            .faults
            .retain(|f| leftover.contains(&f.rank));
        self.stats.recovery_bytes += bytes;
        self.stats.recovery_s += seconds;
        run_world.prof_span(Lane::Recovery, Phase::Adopt, None, t0, t0 + seconds, bytes, None);
        AdoptionReport {
            adopted: pairs,
            released,
            bytes,
            seconds,
        }
    }
}

/// Everything one adoption round did, as seen from a surviving rank:
/// the (dead, spare) pairs spliced in, the spare world ranks released
/// unused, and this rank's share of the adoption bill (survivors serve
/// the fetches passively — get traffic is origin-charged on the
/// spares, so a survivor's `bytes` is just its fence traffic).
#[derive(Clone, Debug, Default)]
pub struct AdoptionReport {
    pub adopted: Vec<(usize, usize)>,
    pub released: Vec<usize>,
    pub bytes: u64,
    pub seconds: f64,
}

/// What a parked spare came back with: released unused, or adopted
/// into a dead rank's grid seat.
pub enum SpareOutcome {
    /// Released without being needed — no deaths, or earlier spares in
    /// the pool covered them all.
    Idle,
    /// Adopted: this rank now owns the dead rank's grid position.
    Adopted(Box<AdoptedSeat>),
}

/// The seat an adopted spare takes over: a session on the remapped
/// full-width grid, synchronized to the survivors' multiply count, plus
/// the dead rank's native operands rebuilt from surviving replica
/// layers. The next `multiply_resident` on this session is
/// bit-identical to — and priced like — a failure-free full-width call.
pub struct AdoptedSeat {
    pub session: PipelineSession,
    pub a: ResidentOperand,
    pub b: ResidentOperand,
    /// Replica-fetch traffic this spare paid to rebuild the shares
    /// (also folded into the session's `recovery_bytes`).
    pub recovery_bytes: u64,
    /// Virtual seconds from the adoption directive to the fence.
    pub recovery_s: f64,
}

/// Park a spare rank until the compute ranks either adopt it (a death
/// left a grid seat to fill) or release it. Collective counterpart of
/// [`PipelineSession::adopt_spares`]: every rank `run_ranks_opts`
/// spawns past the compute world runs this instead of the compute
/// body. `shape` is the compute grid `(rows, cols, layers)`; the
/// layout arguments describe the operand pair the survivors expose
/// (the `A·B` shape of `admit_pair`), and `cfg` must equal the compute
/// ranks' config — the fault plan in it is what makes the adoption
/// pairing agreement-free.
pub fn spare_serve(
    run_world: &CommView,
    shape: (usize, usize, usize),
    cfg: &MultiplyConfig,
    a_layouts: (&BlockLayout, &BlockLayout),
    b_layouts: (&BlockLayout, &BlockLayout),
    mode: Mode,
) -> SpareOutcome {
    let (rows, cols, layers) = shape;
    let compute = rows * cols * layers;
    let spares = run_world.size() - compute;
    let coord = recovery::adoption_coordinator(&cfg.faults, compute);
    let hdr = match run_world.recv(coord, TAG_SPARE_ADOPT) {
        Payload::Empty => return SpareOutcome::Idle,
        Payload::F32(v) => v,
        other => panic!("spare adoption directive must be F32 or Empty, got {other:?}"),
    };
    let t0 = run_world.now();
    let dead = hdr[0] as usize;
    let marks = hdr[1] as u64;
    let multiplies = hdr[2] as u64;
    // replay the survivors' quiescence marks before any paired traffic:
    // the channel checker matches sends and receives by phase, and the
    // directive is the one message allowed to cross epochs
    for _ in 0..marks {
        run_world.phase_mark();
    }
    let pairs = recovery::adoption_pairs(&cfg.faults, compute, spares);
    let me = run_world.rank();
    debug_assert_eq!(
        pairs.iter().find(|(_, s)| *s == me).map(|(d, _)| *d),
        Some(dead),
        "adoption directive disagrees with the pairing derived from the fault plan"
    );
    let members = remap_members(compute, &pairs);
    let g3 = Grid3D::new(run_world.subview(&members), rows, cols, layers);
    // every dead grid position is skipped as a replica owner: positions
    // beyond the spare pool hold a corpse, adopted ones hold a spare
    // with nothing exposed
    let mut dead_positions: Vec<usize> = cfg.faults.iter().map(|f| f.rank).collect();
    dead_positions.sort_unstable();
    dead_positions.dedup();
    let win_a = RmaWindow::new(&g3.world, WIN_ADOPT_A);
    let win_b = RmaWindow::new(&g3.world, WIN_ADOPT_B);
    let (r, c) = g3.grid.coords();
    let lv = sweep_period(rows, cols, layers);
    let vg = VGrid::with_period(rows, cols, lv, r, c);
    let (s0, _) = layer_ticks(lv, layers, g3.layer);
    let slots = vg.slots();
    let b0 = run_world.stats();
    let a_native = fetch_native_share(
        &g3,
        &win_a,
        true,
        &a_start_keys(&vg, &slots, s0),
        &vg,
        &dead_positions,
        a_layouts,
        mode,
    );
    let b_native = fetch_native_share(
        &g3,
        &win_b,
        false,
        &b_start_keys(&vg, &slots, s0),
        &vg,
        &dead_positions,
        b_layouts,
        mode,
    );
    let b1 = run_world.stats();
    g3.world.record_adopt(dead, me);
    // the fence proves every spare is past its last fetch before the
    // survivors retire their exposures; this spare never exposed, so it
    // has no epoch of its own to close
    let leftover: Vec<usize> = dead_positions
        .iter()
        .copied()
        .filter(|d| !pairs.iter().any(|(pd, _)| pd == d))
        .collect();
    recovery::survivor_fence(
        &g3.world,
        &RecoveryPlan {
            kill_now: Vec::new(),
            already_dead: leftover.clone(),
        },
    );
    let recovery_bytes = (b1.bytes_sent - b0.bytes_sent) + (b1.meta_bytes - b0.meta_bytes);
    let recovery_s = run_world.now() - t0;
    let mut cfg = cfg.clone();
    cfg.faults.retain(|f| leftover.contains(&f.rank));
    let mut session = PipelineSession::new(g3, cfg);
    session.multiplies = multiplies;
    session.stats.recovery_bytes += recovery_bytes;
    session.stats.recovery_s += recovery_s;
    run_world.prof_span(
        Lane::Recovery,
        Phase::Adopt,
        None,
        t0,
        t0 + recovery_s,
        recovery_bytes,
        None,
    );
    SpareOutcome::Adopted(Box::new(AdoptedSeat {
        session,
        a: ResidentOperand::from_shares(Some(a_native), None),
        b: ResidentOperand::from_shares(None, Some(b_native)),
        recovery_bytes,
        recovery_s,
    }))
}

/// Member list of the remapped full-width world: grid seat `w` keeps
/// world rank `w` unless a spare adopted it.
fn remap_members(compute: usize, pairs: &[(usize, usize)]) -> Vec<usize> {
    (0..compute)
        .map(|w| {
            pairs
                .iter()
                .find(|(d, _)| *d == w)
                .map_or(w, |&(_, s)| s)
        })
        .collect()
}

/// Rebuild one native-layout share for an adopted spare: for every
/// panel key the dead rank held at its tick-`s0` start layout, pick the
/// lowest layer whose replica owner's position is alive, pull that
/// owner's whole framed share once, and extract the panels locally.
/// Bit-identical to what the dead rank held — framed decode is
/// lossless and panel extraction is a pure function of the replicated
/// operand.
#[allow(clippy::too_many_arguments)]
fn fetch_native_share(
    g3: &Grid3D,
    win: &RmaWindow,
    is_a: bool,
    keys: &[Key],
    vg: &VGrid,
    dead_positions: &[usize],
    layouts: (&BlockLayout, &BlockLayout),
    mode: Mode,
) -> DistMatrix {
    let (rows_l, cols_l) = layouts;
    let mut shares: BTreeMap<usize, DistMatrix> = BTreeMap::new();
    let mut panels: BTreeMap<Key, LocalCsr> = BTreeMap::new();
    for &key in keys {
        let owner = (0..g3.layers)
            .map(|l| {
                recovery::native_share_owner(vg, g3.rows, g3.cols, g3.layers, is_a, key, l)
            })
            .find(|pos| !dead_positions.contains(pos))
            .expect("Unrecoverable: every replica owner of an adoption panel is dead");
        if !shares.contains_key(&owner) {
            let payload = win.try_get(owner).unwrap_or_else(|d| {
                panic!("adoption share of position {owner} unavailable ({d})")
            });
            let local = decode_framed_share(payload, rows_l, cols_l, mode);
            shares.insert(
                owner,
                DistMatrix {
                    rows: rows_l.clone(),
                    cols: cols_l.clone(),
                    row_dist: Distribution::cyclic(g3.rows),
                    col_dist: Distribution::cyclic(g3.cols),
                    coords: g3.grid.coords(),
                    local,
                    mode,
                },
            );
        }
        panels.insert(key, extract_panel(&shares[&owner], vg, key.0, key.1));
    }
    assemble_native(g3, rows_l, cols_l, &panels, mode)
}

/// Assemble skewed panels into one native-layout matrix: the union of
/// the panels' blocks, with the cyclic-distribution metadata the 2.5D
/// driver expects (nativeness is detected from block presence, exactly
/// as for `twofive_operands`-built matrices). Distinct panel keys cover
/// disjoint mod-`L` block classes, so the union has no collisions.
fn assemble_native(
    g3: &Grid3D,
    rows: &BlockLayout,
    cols: &BlockLayout,
    panels: &BTreeMap<Key, LocalCsr>,
    mode: Mode,
) -> DistMatrix {
    let mut row_set: BTreeSet<usize> = BTreeSet::new();
    let mut col_set: BTreeSet<usize> = BTreeSet::new();
    for p in panels.values() {
        row_set.extend(p.row_ids.iter().copied());
        col_set.extend(p.col_ids.iter().copied());
    }
    let row_ids: Vec<usize> = row_set.into_iter().collect();
    let col_ids: Vec<usize> = col_set.into_iter().collect();
    let row_sizes: Vec<usize> = row_ids.iter().map(|&i| rows.block_size(i)).collect();
    let col_sizes: Vec<usize> = col_ids.iter().map(|&j| cols.block_size(j)).collect();

    let mut nz: Vec<(usize, usize)> = Vec::new();
    for p in panels.values() {
        for (_, plr, plc) in p.iter_nnz() {
            nz.push((
                row_ids
                    .binary_search(&p.row_ids[plr])
                    .expect("panel row in union"),
                col_ids
                    .binary_search(&p.col_ids[plc])
                    .expect("panel col in union"),
            ));
        }
    }
    nz.sort_unstable();
    debug_assert!(nz.windows(2).all(|w| w[0] < w[1]), "panel overlap");
    // shared index construction with twofive's native_matrix — the two
    // native-layout builders can't drift apart
    let mut local = LocalCsr::from_pattern_store(
        row_ids,
        col_ids,
        row_sizes,
        col_sizes,
        &nz,
        mode == Mode::Model,
    );
    if mode == Mode::Real {
        for p in panels.values() {
            for (pb, plr, plc) in p.iter_nnz().collect::<Vec<_>>() {
                let lr = local
                    .row_ids
                    .binary_search(&p.row_ids[plr])
                    .expect("assembled row");
                let lc = local
                    .col_ids
                    .binary_search(&p.col_ids[plc])
                    .expect("assembled col");
                let bi = local.find(lr, lc).expect("assembled pattern");
                let area = local.area_of(lr, lc);
                local
                    .store
                    .block_mut(bi, area)
                    .copy_from_slice(p.store.block(pb, area));
            }
        }
    }
    debug_assert!(local.check_invariants().is_ok());
    let (r, c) = g3.grid.coords();
    DistMatrix {
        rows: rows.clone(),
        cols: cols.clone(),
        row_dist: Distribution::cyclic(g3.rows),
        col_dist: Distribution::cyclic(g3.cols),
        coords: (r, c),
        local,
        mode,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{run_ranks, NetModel};
    use crate::matrix::matrix::{dense_reference, Fill};
    use crate::multiply::engine::EngineOpts;
    use crate::util::prop::assert_allclose;

    fn cfg(transport: Transport, threads: usize, densify: bool) -> MultiplyConfig {
        MultiplyConfig {
            engine: EngineOpts {
                threads,
                densify,
                stack_cap: 48,
                cpu_coexec: true,
            },
            transport,
            ..Default::default()
        }
    }

    fn canonical(
        g3: &Grid3D,
        m: usize,
        n: usize,
        block: usize,
        mode: Mode,
        seed: u64,
    ) -> DistMatrix {
        // layers > 0 start from zeros: admit's broadcast must deliver
        // the elements, like the canonical 2.5D entry path
        let fill = match mode {
            Mode::Model => Fill::Zero,
            Mode::Real if g3.layer == 0 => Fill::Random { seed },
            Mode::Real => Fill::Zero,
        };
        DistMatrix::dense_cyclic(
            m,
            n,
            block,
            (g3.rows, g3.cols),
            g3.grid.coords(),
            mode,
            fill,
        )
    }

    fn resident_case(rows: usize, cols: usize, layers: usize, dim: usize, transport: Transport) {
        let p = rows * cols * layers;
        let iters = 3usize;
        let out = run_ranks(p, NetModel::aries(2), move |world| {
            let g3 = Grid3D::new(world, rows, cols, layers);
            let a = canonical(&g3, dim, dim, 4, Mode::Real, 71);
            let b = canonical(&g3, dim, dim, 4, Mode::Real, 72);
            let mut sess = PipelineSession::new(g3, cfg(transport, 2, true));
            let (ra, rb) = sess.admit_pair(a, b);
            let mut last = Vec::new();
            for _ in 0..iters {
                let out = sess.multiply_resident(&ra, &rb).unwrap();
                assert_eq!(out.stats.repl_bytes, 0, "resident calls never replicate");
                let plan = out.stats.plan.as_ref().unwrap();
                assert_eq!(plan.source, "resident");
                assert!(!plan.charged_replication);
                let mut dense = vec![0.0f32; dim * dim];
                out.c.add_into_dense(&mut dense);
                last = dense;
            }
            assert_eq!(sess.multiplies(), iters as u64);
            (last, sess.repl_bytes())
        });
        // some rank pays setup traffic (identity-skew ranks may not)
        assert!(out.iter().map(|(_, b)| *b).sum::<u64>() > 0);
        let mut got = vec![0.0f32; dim * dim];
        for (part, _) in &out {
            for (g, x) in got.iter_mut().zip(part.iter()) {
                *g += x;
            }
        }
        let ar = dense_reference(&BlockLayout::new(dim, 4), &BlockLayout::new(dim, 4), 71);
        let br = dense_reference(&BlockLayout::new(dim, 4), &BlockLayout::new(dim, 4), 72);
        let mut want = vec![0.0f32; dim * dim];
        crate::backend::smm_cpu::gemm_blocked(dim, dim, dim, &ar, &br, &mut want);
        assert_allclose(&got, &want, 2e-3, 2e-3).unwrap_or_else(|e| {
            panic!("resident {rows}x{cols}x{layers} dim {dim} {transport}: {e}")
        });
    }

    #[test]
    fn resident_multiply_matches_reference_two_layers() {
        resident_case(2, 2, 2, 24, Transport::TwoSided);
        resident_case(2, 2, 2, 24, Transport::OneSided);
    }

    #[test]
    fn resident_multiply_matches_reference_four_layers() {
        resident_case(2, 2, 4, 32, Transport::TwoSided);
        resident_case(2, 2, 4, 32, Transport::OneSided);
    }

    #[test]
    fn resident_single_layer_amortizes_the_cannon_skew() {
        // layers = 1: no replication, but the pre-skew still amortizes
        resident_case(2, 2, 1, 24, Transport::TwoSided);
    }

    #[test]
    fn resident_rect_grid_and_ragged_blocks() {
        resident_case(1, 2, 2, 18, Transport::TwoSided);
        // 26 = 3*8 + 2 ragged tail
        let out = run_ranks(8, NetModel::aries(2), move |world| {
            let g3 = Grid3D::new(world, 2, 2, 2);
            let a = canonical(&g3, 26, 26, 8, Mode::Real, 71);
            let b = canonical(&g3, 26, 26, 8, Mode::Real, 72);
            let mut sess = PipelineSession::new(g3, cfg(Transport::TwoSided, 2, false));
            let (ra, rb) = sess.admit_pair(a, b);
            let out = sess.multiply_resident(&ra, &rb).unwrap();
            let mut dense = vec![0.0f32; 26 * 26];
            out.c.add_into_dense(&mut dense);
            dense
        });
        let mut got = vec![0.0f32; 26 * 26];
        for part in out {
            for (g, x) in got.iter_mut().zip(part.iter()) {
                *g += x;
            }
        }
        let ar = dense_reference(&BlockLayout::new(26, 8), &BlockLayout::new(26, 8), 71);
        let br = dense_reference(&BlockLayout::new(26, 8), &BlockLayout::new(26, 8), 72);
        let mut want = vec![0.0f32; 26 * 26];
        crate::backend::smm_cpu::gemm_blocked(26, 26, 26, &ar, &br, &mut want);
        assert_allclose(&got, &want, 2e-3, 2e-3).unwrap();
    }

    #[test]
    fn admitted_shares_match_native_operands() {
        // the pre-skew must land blocks exactly where twofive_operands
        // puts them — same ids, same per-layer coverage
        use crate::multiply::twofive::twofive_operands;
        let (rows, cols, layers, dim) = (2usize, 2usize, 2usize, 32usize);
        let out = run_ranks(rows * cols * layers, NetModel::ideal(), move |world| {
            let g3 = Grid3D::new(world, rows, cols, layers);
            let (na, nb) = twofive_operands(&g3, dim, dim, dim, 4, Mode::Model, 1, 2);
            let a = canonical(&g3, dim, dim, 4, Mode::Model, 1);
            let b = canonical(&g3, dim, dim, 4, Mode::Model, 2);
            let mut sess = PipelineSession::new(g3, cfg(Transport::TwoSided, 1, false));
            let (ra, rb) = sess.admit_pair(a, b);
            let sa = ra.a_share().unwrap();
            let sb = rb.b_share().unwrap();
            (
                sa.local.row_ids == na.local.row_ids && sa.local.col_ids == na.local.col_ids,
                sb.local.row_ids == nb.local.row_ids && sb.local.col_ids == nb.local.col_ids,
                sa.local.nnz() == na.local.nnz(),
            )
        });
        for (a_ok, b_ok, nnz_ok) in out {
            assert!(a_ok && b_ok && nnz_ok);
        }
    }

    #[test]
    fn elementwise_ops_preserve_residency() {
        // scale/axpy on resident handles stay consistent with the same
        // ops applied before admission
        let (rows, cols, layers, dim) = (2usize, 1usize, 2usize, 16usize);
        let out = run_ranks(rows * cols * layers, NetModel::aries(2), move |world| {
            let g3 = Grid3D::new(world, rows, cols, layers);
            let a = canonical(&g3, dim, dim, 4, Mode::Real, 71);
            let b = canonical(&g3, dim, dim, 4, Mode::Real, 72);
            let mut sess = PipelineSession::new(g3, cfg(Transport::TwoSided, 1, false));
            let mut ra = sess.admit(a, Sides::Both);
            let rb = sess.admit(b, Sides::B);
            // ra ← 2·ra − rb requires rb on both sides; re-admit instead
            ra.scale(2.0);
            let out = sess.multiply_resident(&ra, &rb).unwrap();
            let mut dense = vec![0.0f32; dim * dim];
            out.c.add_into_dense(&mut dense);
            dense
        });
        let mut got = vec![0.0f32; dim * dim];
        for part in out {
            for (g, x) in got.iter_mut().zip(part.iter()) {
                *g += x;
            }
        }
        let ar = dense_reference(&BlockLayout::new(dim, 4), &BlockLayout::new(dim, 4), 71);
        let br = dense_reference(&BlockLayout::new(dim, 4), &BlockLayout::new(dim, 4), 72);
        let mut want = vec![0.0f32; dim * dim];
        let scaled: Vec<f32> = ar.iter().map(|x| 2.0 * x).collect();
        crate::backend::smm_cpu::gemm_blocked(dim, dim, dim, &scaled, &br, &mut want);
        assert_allclose(&got, &want, 2e-3, 2e-3).unwrap();
    }
}
