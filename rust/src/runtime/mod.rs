//! PJRT runtime: load AOT Pallas/JAX artifacts and execute them.
//!
//! This is the request-path bridge to the compute layer: HLO *text*
//! emitted once by `python/compile/aot.py` is parsed
//! (`HloModuleProto::from_text_file` — the text parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1
//! would reject in proto form), compiled on the PJRT CPU client, and the
//! executable is cached per variant. Python never runs here.
//!
//! One `Runtime` per rank thread: the `xla` crate's handles are raw
//! C-pointer wrappers without `Send`/`Sync`, and per-thread clients also
//! mirror how each MPI rank owns its own cuBLAS context in the paper.
//!
//! The PJRT execution path is gated behind the `pjrt` cargo feature (the
//! `xla` crate must be supplied by the build environment). Without the
//! feature, [`Manifest`] parsing and tile planning still work, and
//! [`Runtime::load`] reports that execution is unavailable — every
//! multiply then runs on the CPU microkernel fallback.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use crate::util::error::{Context, Error, Result};

use crate::util::json::Json;

/// One AOT artifact described by `manifest.json`.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub path: PathBuf,
    pub kind: VariantKind,
    /// Input shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Real FLOPs per execution.
    pub flops: u64,
    /// Analytic VMEM footprint of the kernel (bytes) — L1 perf estimate.
    pub vmem_bytes: u64,
    /// Analytic MXU utilization estimate — L1 perf estimate.
    pub mxu_efficiency: f64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum VariantKind {
    /// `C += A·B` over a (tile × tile) panel.
    GemmAcc { tile: usize },
    /// Stack chunk: `C[i] += A[i]·B[i]`, blocks padded (mp, np, kp).
    Smm {
        m: usize,
        n: usize,
        k: usize,
        mp: usize,
        np: usize,
        kp: usize,
        s: usize,
    },
}

/// Parsed manifest (no PJRT needed — usable by planning/tests).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if j.get("format").as_usize() != Some(1) {
            return Err(Error::msg("unsupported manifest format"));
        }
        let mut variants = Vec::new();
        for v in j.get("variants").as_arr().unwrap_or(&[]) {
            let name = v.get("name").as_str().context("variant name")?.to_string();
            let path = dir.join(v.get("path").as_str().context("variant path")?);
            let kind = match v.get("kind").as_str() {
                Some("gemm_acc") => VariantKind::GemmAcc {
                    tile: v.get("tile").as_usize().context("tile")?,
                },
                Some("smm") => VariantKind::Smm {
                    m: v.get("m").as_usize().context("m")?,
                    n: v.get("n").as_usize().context("n")?,
                    k: v.get("k").as_usize().context("k")?,
                    mp: v.get("mp").as_usize().context("mp")?,
                    np: v.get("np").as_usize().context("np")?,
                    kp: v.get("kp").as_usize().context("kp")?,
                    s: v.get("s").as_usize().context("s")?,
                },
                other => return Err(Error::msg(format!("unknown variant kind {other:?}"))),
            };
            let inputs = v
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|dims| {
                    dims.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect()
                })
                .collect();
            variants.push(Variant {
                name,
                path,
                kind,
                inputs,
                flops: v.get("flops").as_f64().unwrap_or(0.0) as u64,
                vmem_bytes: v.get("vmem_bytes").as_f64().unwrap_or(0.0) as u64,
                mxu_efficiency: v.get("mxu_efficiency").as_f64().unwrap_or(0.0),
            });
        }
        Ok(Manifest { variants })
    }

    pub fn find(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Available gemm tiles, ascending.
    pub fn gemm_tiles(&self) -> Vec<usize> {
        let mut t: Vec<usize> = self
            .variants
            .iter()
            .filter_map(|v| match v.kind {
                VariantKind::GemmAcc { tile } => Some(tile),
                _ => None,
            })
            .collect();
        t.sort_unstable();
        t
    }

    /// Available SMM block sizes (uniform m=n=k), ascending.
    pub fn smm_sizes(&self) -> Vec<usize> {
        let mut t: Vec<usize> = self
            .variants
            .iter()
            .filter_map(|v| match v.kind {
                VariantKind::Smm { m, n, k, .. } if m == n && n == k => Some(m),
                _ => None,
            })
            .collect();
        t.sort_unstable();
        t
    }
}

/// Default artifacts directory: `$DBCSR_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DBCSR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// A per-thread PJRT execution context with an executable cache.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub manifest: Manifest,
    /// Cumulative executions (perf accounting).
    pub calls: RefCell<HashMap<String, u64>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client and load the manifest.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::msg(format!("PJRT cpu client: {e:?}")))?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
        })
    }

    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let var = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::msg(format!("unknown variant {name}")))?;
        let proto = xla::HloModuleProto::from_text_file(
            var.path.to_str().context("artifact path utf8")?,
        )
        .map_err(|e| Error::msg(format!("parsing HLO text {}: {e:?}", var.path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::msg(format!("compiling {name}: {e:?}")))?;
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a variant on raw f32 buffers (shapes per the manifest).
    /// Returns the (single, tupled) output buffer.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let var = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::msg(format!("unknown variant {name}")))?
            .clone();
        if inputs.len() != var.inputs.len() {
            return Err(Error::msg(format!(
                "{name}: expected {} inputs, got {}",
                var.inputs.len(),
                inputs.len()
            )));
        }
        let exe = self.executable(name)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, dims) in inputs.iter().zip(var.inputs.iter()) {
            let want: usize = dims.iter().product();
            if buf.len() != want {
                return Err(Error::msg(format!(
                    "{name}: input length {} != shape {:?}",
                    buf.len(),
                    dims
                )));
            }
            let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&idims)
                .map_err(|e| Error::msg(format!("reshape: {e:?}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::msg(format!("execute {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::msg(format!("to_literal: {e:?}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| Error::msg(format!("untuple: {e:?}")))?
            .to_vec::<f32>()
            .map_err(|e| Error::msg(format!("to_vec: {e:?}")))?;
        *self.calls.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        Ok(out)
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Without the `pjrt` feature there is no execution backend: loading
    /// fails with a clear message and all multiplies use the CPU
    /// microkernel fallback (no `Runtime` is ever constructed).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let _ = Manifest::load(dir)?; // surface manifest problems first
        Err(Error::msg(
            "built without the `pjrt` feature: PJRT execution unavailable \
             (add the environment's `xla` crate to rust/Cargo.toml and \
             rebuild with `--features pjrt`)",
        ))
    }

    /// Stub: unreachable in practice (`load` never yields a Runtime).
    pub fn execute(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        Err(Error::msg(format!(
            "pjrt feature disabled: cannot execute {name}"
        )))
    }
}

impl Runtime {
    /// Pick the best gemm tile for a (rows × cols) panel: the largest tile
    /// not wasting more than ~35% padding, else the smallest.
    pub fn pick_gemm_tile(&self, rows: usize, cols: usize, inner: usize) -> Option<usize> {
        let tiles = self.manifest.gemm_tiles();
        let waste = |t: usize| {
            let pad = |x: usize| x.div_ceil(t) * t;
            let padded = pad(rows) as f64 * pad(cols) as f64 * pad(inner) as f64;
            padded / (rows.max(1) as f64 * cols.max(1) as f64 * inner.max(1) as f64)
        };
        tiles
            .iter()
            .rev()
            .find(|&&t| waste(t) < 1.35)
            .or_else(|| tiles.first())
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        // tests run from the crate root
        artifacts_dir()
    }

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn manifest_loads() {
        let m = Manifest::load(&dir()).expect("run `make artifacts` first");
        assert!(m.gemm_tiles().contains(&128));
        assert!(m.smm_sizes().contains(&22));
        let v = m.find("gemm_128").unwrap();
        assert_eq!(v.inputs.len(), 3);
        assert!(v.flops > 0);
    }

    #[test]
    #[ignore = "requires `make artifacts` and --features pjrt"]
    fn gemm_artifact_executes_correctly() {
        let rt = Runtime::load(&dir()).unwrap();
        let t = 128usize;
        // C += A*B with A = I, B = ramp, C = 1 → out = ramp + 1
        let mut a = vec![0.0f32; t * t];
        for i in 0..t {
            a[i * t + i] = 1.0;
        }
        let b: Vec<f32> = (0..t * t).map(|i| (i % 100) as f32 * 0.01).collect();
        let c = vec![1.0f32; t * t];
        let out = rt.execute("gemm_128", &[&a, &b, &c]).unwrap();
        for i in 0..t * t {
            assert!(
                (out[i] - (b[i] + 1.0)).abs() < 1e-4,
                "i={i}: {} vs {}",
                out[i],
                b[i] + 1.0
            );
        }
    }

    #[test]
    #[ignore = "requires `make artifacts` and --features pjrt"]
    fn smm_artifact_executes_correctly() {
        let rt = Runtime::load(&dir()).unwrap();
        let v = rt.manifest.find("smm_4").unwrap().clone();
        let (s, m4) = match v.kind {
            VariantKind::Smm { s, m, .. } => (s, m),
            _ => panic!(),
        };
        assert_eq!(m4, 4);
        // A[i] = i * I, B[i] = ones, C = 0 → out[i] = i * ones
        let mut a = vec![0.0f32; s * 16];
        for i in 0..s {
            for d in 0..4 {
                a[i * 16 + d * 4 + d] = i as f32;
            }
        }
        let b = vec![1.0f32; s * 16];
        let c = vec![0.0f32; s * 16];
        let out = rt.execute("smm_4", &[&a, &b, &c]).unwrap();
        for i in 0..s {
            for e in 0..16 {
                assert!(
                    (out[i * 16 + e] - i as f32).abs() < 1e-4,
                    "entry {i} elem {e}"
                );
            }
        }
    }

    #[test]
    #[ignore = "requires `make artifacts` and --features pjrt"]
    fn execute_rejects_bad_shapes() {
        let rt = Runtime::load(&dir()).unwrap();
        let small = vec![0.0f32; 4];
        assert!(rt.execute("gemm_128", &[&small, &small, &small]).is_err());
        assert!(rt.execute("nonexistent", &[]).is_err());
    }

    #[test]
    #[ignore = "requires `make artifacts` and --features pjrt"]
    fn executable_cache_reuses() {
        let rt = Runtime::load(&dir()).unwrap();
        let t = 128 * 128;
        let z = vec![0.0f32; t];
        let _ = rt.execute("gemm_128", &[&z, &z, &z]).unwrap();
        let _ = rt.execute("gemm_128", &[&z, &z, &z]).unwrap();
        assert_eq!(rt.calls.borrow()["gemm_128"], 2);
    }

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn tile_picker_prefers_low_waste() {
        let rt = match Runtime::load(&dir()) {
            Ok(rt) => rt,
            Err(_) => return, // no pjrt build: covered by manifest-only path
        };
        // a 700x700x700 panel: 512 pads to 1024³ (3.1x waste) → pick 256
        // wait: 700/256→768³ (1.32x) ok
        let t = rt.pick_gemm_tile(700, 700, 700).unwrap();
        assert!(t == 256 || t == 128, "picked {t}");
        // a big clean panel picks the big tile
        assert_eq!(rt.pick_gemm_tile(2048, 2048, 2048), Some(512));
    }

    #[test]
    fn missing_manifest_reports_path() {
        let e = Manifest::load(Path::new("/nonexistent-artifacts")).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("manifest.json"), "got: {msg}");
    }
}
