//! Small self-contained utilities.
//!
//! The offline crate set has no `rand`, `serde`, `criterion` or `proptest`;
//! these modules provide the slices of each that the library needs
//! (documented as substitutions in DESIGN.md §3).

pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

/// Round `x` up to the next multiple of `q` (q > 0).
#[inline]
pub fn round_up(x: usize, q: usize) -> usize {
    debug_assert!(q > 0);
    x.div_ceil(q) * q
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(x: usize, q: usize) -> usize {
    debug_assert!(q > 0);
    x.div_ceil(q)
}

/// Split `n` items into `parts` contiguous chunks as evenly as possible;
/// returns the (start, len) of chunk `idx`. The first `n % parts` chunks
/// get one extra item (the MPI_Scatterv convention).
#[inline]
pub fn even_chunk(n: usize, parts: usize, idx: usize) -> (usize, usize) {
    debug_assert!(idx < parts);
    let base = n / parts;
    let extra = n % parts;
    let len = base + usize::from(idx < extra);
    let start = idx * base + idx.min(extra);
    (start, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn even_chunk_partitions() {
        for n in [0usize, 1, 7, 12, 100] {
            for parts in [1usize, 2, 3, 5, 12] {
                let mut covered = 0;
                let mut next = 0;
                for i in 0..parts {
                    let (s, l) = even_chunk(n, parts, i);
                    assert_eq!(s, next, "chunks must be contiguous");
                    next = s + l;
                    covered += l;
                }
                assert_eq!(covered, n, "chunks must cover 0..n");
            }
        }
    }

    #[test]
    fn even_chunk_balance() {
        // max-min difference never exceeds 1
        let lens: Vec<usize> = (0..5).map(|i| even_chunk(13, 5, i).1).collect();
        assert_eq!(lens.iter().max().unwrap() - lens.iter().min().unwrap(), 1);
    }
}
