//! Descriptive statistics + the counters the multiply engine reports.

/// Summary statistics over a sample of measurements.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Relative fluctuation (std/mean) — the paper reports < 5%.
    pub fn rel_fluctuation(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// The algorithm/topology decision one multiplication ran with, plus the
/// planner's cost prediction for it — surfaced through
/// [`MultiplyStats::plan`] so benches and the planner test suite can
/// observe what `Algorithm::Auto` (or an explicit request) resolved to.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanSummary {
    /// "cannon" | "2.5d" | "tall-skinny".
    pub algorithm: String,
    /// Layer-grid factorization (layers = 1 for Cannon / tall-skinny).
    pub rows: usize,
    pub cols: usize,
    pub layers: usize,
    /// Who decided: "model" (planner argmin), "layout" (operand-layout
    /// resolution of `Algorithm::Auto`), "resident" (a
    /// `PipelineSession` steady-state call), or "explicit"
    /// (caller-fixed).
    pub source: &'static str,
    /// Whether the one-time A/B layer replication was charged to this
    /// plan's objective. `true` for cold one-shot plans; `false` for
    /// steady-state candidates (operands layer-resident, replication
    /// amortized) — without this field `--plan-verbose` and
    /// `MultiplyStats::plan` would mislabel steady-state plans as
    /// one-shot.
    pub charged_replication: bool,
    /// The multiply count the plan was priced for (1 = one-shot; > 1 =
    /// a steady-state horizon amortizing the replication).
    pub horizon: usize,
    /// Planner prediction for the executed plan (0 when no cost model
    /// covers the algorithm, e.g. tall-skinny).
    pub predicted_seconds: f64,
    pub predicted_comm_s: f64,
}

/// Counters accumulated by one distributed multiplication, aggregated over
/// ranks. These drive both the virtual-clock model and the bench reports.
#[derive(Clone, Debug, Default)]
pub struct MultiplyStats {
    /// Number of stacks processed (Generation output).
    pub stacks: u64,
    /// Total small-block multiplications across all stacks.
    pub block_mults: u64,
    /// FLOPs actually computed (2*m*n*k per block mult).
    pub flops: u64,
    /// Bytes moved rank-to-rank (Cannon shifts / TS reductions).
    pub comm_bytes: u64,
    /// The metadata share of `comm_bytes`: the block-index streams of
    /// the sparse-panel wire format (`multiply::sparse_exchange`). The
    /// price of shipping sparsity patterns, separated from the element
    /// payload so occupancy-proportionality is checkable.
    pub meta_bytes: u64,
    /// Number of point-to-point messages.
    pub comm_msgs: u64,
    /// Virtual seconds the rank's clock advanced while blocked on
    /// communication (receives / RMA epoch closes) — the transport
    /// comparison metric of `bench_fig_2p5d`.
    pub comm_wait_s: f64,
    /// Virtual seconds of transfer time the double-buffered shift /
    /// deferred-reduce overlap hid behind compute: the modeled
    /// synchronous cost of the overlapped transfers minus the wait they
    /// actually booked. `comm_wait_s` keeps only the unhidden
    /// remainder, so `comm_wait_s + overlap_hidden_s` bounds what the
    /// same schedule would have waited synchronously. Zero whenever
    /// `MultiplyConfig::overlap` is off.
    pub overlap_hidden_s: f64,
    /// Bytes of operand-residency setup (2.5D layer replication +
    /// pre-skew into the native layout) — the `repl_` bucket, charged
    /// once per admitted operand by whoever makes it resident
    /// (`PipelineSession::admit`, the harness's in-run replication, a
    /// Newton step re-admitting its product). Always 0 on the per-call
    /// counters of a steady-state `multiply_resident`, which is the
    /// amortization the bucket makes observable.
    pub repl_bytes: u64,
    /// Virtual seconds of the same residency setup.
    pub repl_s: f64,
    /// Bytes staged host→device.
    pub h2d_bytes: u64,
    /// Bytes staged device→host.
    pub d2h_bytes: u64,
    /// Bytes copied by densification/undensification.
    pub densify_bytes: u64,
    /// Stacks executed on the (simulated) GPU vs host CPU.
    pub gpu_stacks: u64,
    pub cpu_stacks: u64,
    /// Peak simulated device-memory occupancy, bytes.
    pub dev_mem_peak: u64,
    /// Result blocks dropped by on-the-fly filtering
    /// (`MultiplyConfig::filter_eps`) after the accumulation.
    pub filtered_blocks: u64,
    /// Bytes fetched from replica layers to heal a detected rank death
    /// mid-multiply (`multiply::recovery`): framed operand shares pulled
    /// over `WIN_RECOVER_A`/`B`. Always 0 on a failure-free run.
    pub recovery_bytes: u64,
    /// Virtual seconds this rank spent on recovery — blocked on a dead
    /// peer's silence, fetching replica shares, re-running the lost
    /// rank's slot-ticks, and the survivor fence before window teardown.
    pub recovery_s: f64,
    /// Wire bytes the reliability layer spent on frames that did not
    /// deliver: dropped, duplicated, and corrupt transmissions plus
    /// their retransmissions (`dist::faultnet`). Disjoint from
    /// `comm_bytes`, which keeps counting goodput only — a fault-free
    /// run has `retrans_bytes == 0` no matter the fault plan knobs.
    pub retrans_bytes: u64,
    /// Virtual seconds of retransmission overhead: backoff waits and
    /// injected delay spikes charged by the fault plan. A conservative
    /// (never under-counting) bound on the slowdown vs the same run on
    /// a clean fabric.
    pub retrans_s: f64,
    /// True when `MultiplyConfig::overlap` was requested but an active
    /// fault/recovery plan forced the shifts synchronous — the overlap
    /// machinery cannot heal a dead ring mid-flight, and silently
    /// dropping the optimization would make `--overlap` runs lie.
    /// `merge` ORs, so one downgraded call marks the aggregate.
    pub overlap_downgraded: bool,
    /// Occupancy accounting: present and total block slots of this
    /// rank's operand and result shares (result counted *after*
    /// filtering). Kept as counter pairs so `merge` aggregates exactly;
    /// read through [`MultiplyStats::occupancy_a`] and friends.
    pub a_nnz_blocks: u64,
    pub a_total_blocks: u64,
    pub b_nnz_blocks: u64,
    pub b_total_blocks: u64,
    pub c_nnz_blocks: u64,
    pub c_total_blocks: u64,
    /// The plan this multiplication ran with (identical on every rank of
    /// one collective call; `merge` keeps the first).
    pub plan: Option<PlanSummary>,
}

impl MultiplyStats {
    /// Fraction of present A blocks over the counted block slots
    /// (0 when nothing was counted — e.g. stats that never saw a
    /// multiply).
    pub fn occupancy_a(&self) -> f64 {
        occ(self.a_nnz_blocks, self.a_total_blocks)
    }
    pub fn occupancy_b(&self) -> f64 {
        occ(self.b_nnz_blocks, self.b_total_blocks)
    }
    /// Result occupancy after filtering — the observable fill-in
    /// control of `MultiplyConfig::filter_eps`.
    pub fn occupancy_c(&self) -> f64 {
        occ(self.c_nnz_blocks, self.c_total_blocks)
    }

    pub fn merge(&mut self, o: &MultiplyStats) {
        self.stacks += o.stacks;
        self.block_mults += o.block_mults;
        self.flops += o.flops;
        self.comm_bytes += o.comm_bytes;
        self.meta_bytes += o.meta_bytes;
        self.comm_msgs += o.comm_msgs;
        self.comm_wait_s += o.comm_wait_s;
        self.overlap_hidden_s += o.overlap_hidden_s;
        self.filtered_blocks += o.filtered_blocks;
        self.recovery_bytes += o.recovery_bytes;
        self.recovery_s += o.recovery_s;
        self.retrans_bytes += o.retrans_bytes;
        self.retrans_s += o.retrans_s;
        self.overlap_downgraded |= o.overlap_downgraded;
        self.a_nnz_blocks += o.a_nnz_blocks;
        self.a_total_blocks += o.a_total_blocks;
        self.b_nnz_blocks += o.b_nnz_blocks;
        self.b_total_blocks += o.b_total_blocks;
        self.c_nnz_blocks += o.c_nnz_blocks;
        self.c_total_blocks += o.c_total_blocks;
        self.repl_bytes += o.repl_bytes;
        self.repl_s += o.repl_s;
        self.h2d_bytes += o.h2d_bytes;
        self.d2h_bytes += o.d2h_bytes;
        self.densify_bytes += o.densify_bytes;
        self.gpu_stacks += o.gpu_stacks;
        self.cpu_stacks += o.cpu_stacks;
        self.dev_mem_peak = self.dev_mem_peak.max(o.dev_mem_peak);
        if self.plan.is_none() {
            self.plan = o.plan.clone();
        }
    }
}

fn occ(nnz: u64, total: u64) -> f64 {
    if total == 0 {
        0.0
    } else {
        nnz as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_odd_median() {
        assert_eq!(Summary::of(&[5.0, 1.0, 3.0]).median, 3.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn merge_adds_and_maxes() {
        let mut a = MultiplyStats {
            stacks: 1,
            flops: 100,
            dev_mem_peak: 50,
            repl_bytes: 10,
            repl_s: 0.25,
            ..Default::default()
        };
        let b = MultiplyStats {
            stacks: 2,
            flops: 200,
            dev_mem_peak: 30,
            repl_bytes: 5,
            repl_s: 0.5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.stacks, 3);
        assert_eq!(a.flops, 300);
        assert_eq!(a.dev_mem_peak, 50);
        assert_eq!(a.repl_bytes, 15);
        assert_eq!(a.repl_s, 0.75);
    }

    #[test]
    fn occupancies_aggregate_as_weighted_means() {
        let mut a = MultiplyStats {
            a_nnz_blocks: 2,
            a_total_blocks: 10,
            c_nnz_blocks: 1,
            c_total_blocks: 4,
            meta_bytes: 8,
            filtered_blocks: 3,
            ..Default::default()
        };
        let b = MultiplyStats {
            a_nnz_blocks: 8,
            a_total_blocks: 10,
            c_nnz_blocks: 3,
            c_total_blocks: 4,
            meta_bytes: 16,
            filtered_blocks: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.occupancy_a(), 0.5);
        assert_eq!(a.occupancy_c(), 0.5);
        assert_eq!(a.occupancy_b(), 0.0, "uncounted defaults to zero");
        assert_eq!(a.meta_bytes, 24);
        assert_eq!(a.filtered_blocks, 4);
    }

    #[test]
    fn merge_sums_retrans_and_ors_the_downgrade() {
        let mut a = MultiplyStats {
            retrans_bytes: 7,
            retrans_s: 0.5,
            ..Default::default()
        };
        a.merge(&MultiplyStats {
            retrans_bytes: 3,
            retrans_s: 0.25,
            overlap_downgraded: true,
            ..Default::default()
        });
        assert_eq!(a.retrans_bytes, 10);
        assert!((a.retrans_s - 0.75).abs() < 1e-12);
        assert!(a.overlap_downgraded, "one downgraded call marks the aggregate");
        a.merge(&MultiplyStats::default());
        assert!(a.overlap_downgraded, "the flag is sticky");
    }

    #[test]
    fn merge_keeps_first_plan() {
        let plan = |layers: usize| PlanSummary {
            algorithm: "2.5d".into(),
            rows: 2,
            cols: 4,
            layers,
            source: "model",
            charged_replication: true,
            horizon: 1,
            predicted_seconds: 1.0,
            predicted_comm_s: 0.5,
        };
        let mut a = MultiplyStats::default();
        a.merge(&MultiplyStats {
            plan: Some(plan(2)),
            ..Default::default()
        });
        a.merge(&MultiplyStats {
            plan: Some(plan(4)),
            ..Default::default()
        });
        assert_eq!(a.plan.as_ref().unwrap().layers, 2);
    }
}
