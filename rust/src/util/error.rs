//! Minimal error type + context helpers (anyhow is not in the offline
//! crate set).
//!
//! `Error` is a single-message error; [`Context`] mirrors the
//! `anyhow::Context` extension trait for `Result` and `Option` so
//! fallible loaders can annotate failures as they bubble up.

use std::fmt;

/// A string-message error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// `anyhow::Context` analog: attach a message to the error path.
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| Error(format!("{msg}: value missing")))
    }
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(format!("{}: value missing", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_annotates_result() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("loading file").unwrap_err();
        assert!(e.to_string().contains("loading file"));
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_annotates_option() {
        let o: Option<u32> = None;
        let e = o.with_context(|| "field x".to_string()).unwrap_err();
        assert!(e.to_string().contains("field x"));
    }

    #[test]
    fn some_passes_through() {
        assert_eq!(Some(3).context("nope").unwrap(), 3);
    }
}
