//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**).
//!
//! Used for matrix fill, workload generation and property tests; seeded
//! everywhere so every experiment is bit-reproducible.

/// xoshiro256** seeded via SplitMix64 — good statistical quality, tiny code.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (SplitMix64-expanded to full state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        // all-zero state is invalid; SplitMix64 of any seed avoids it,
        // but guard anyway.
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [-1, 1) — the matrix-fill distribution.
    pub fn next_f32_sym(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform in [0, n) (n > 0), unbiased via rejection.
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG (for per-rank / per-thread streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.next_below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        for _ in 0..1_000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
        }
        assert_eq!(r.range(4, 4), 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn rough_uniformity() {
        // mean of [0,1) uniforms ≈ 0.5 within 1%
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
