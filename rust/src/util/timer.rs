//! Wallclock timing helpers for the bench harness (criterion substitute).

use std::time::Instant;

/// Measure `f`, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Repeat `f` `reps` times after `warmup` runs; returns per-rep seconds.
pub fn bench(warmup: usize, reps: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Black-box: prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_value() {
        let (v, dt) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn bench_counts_reps() {
        let mut calls = 0;
        let samples = bench(2, 5, || calls += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(calls, 7);
    }
}
