//! Minimal JSON parser + writer (serde is not in the offline crate set).
//!
//! Scope: what the library needs — parsing `artifacts/manifest.json` and
//! autotune model files, and emitting bench/experiment records. Supports
//! the full JSON value grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (adequate for manifests/benches).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access; returns Null for missing keys (chainable).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    /// Array index access; Null when out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|v| v.get(i)).unwrap_or(&NULL)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`Json::to_string()` comes from `Display`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
// byte counters (`CommStats`/`MultiplyStats`) are u64; precision loss
// above 2^53 is acceptable for bench records
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                _ => {
                    // multi-byte UTF-8: copy the full sequence
                    let s = &self.b[self.i..];
                    let ch = std::str::from_utf8(&s[..s.len().min(4)])
                        .ok()
                        .and_then(|t| t.chars().next())
                        .or_else(|| {
                            std::str::from_utf8(s).ok().and_then(|t| t.chars().next())
                        })
                        .ok_or_else(|| self.err("invalid utf8"))?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode() {
        let v = Json::parse(r#""é café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ✓"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"format":1,"dtype":"f32","variants":[{"name":"gemm_128","path":"gemm_128.hlo.txt","kind":"gemm_acc","tile":128,"inputs":[[128,128],[128,128],[128,128]]}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").as_usize(), Some(1));
        let var = v.get("variants").idx(0);
        assert_eq!(var.get("name").as_str(), Some("gemm_128"));
        assert_eq!(var.get("inputs").idx(0).idx(1).as_usize(), Some(128));
    }

    #[test]
    fn builder_obj() {
        let j = obj([("x", 1usize.into()), ("s", "hi".into())]);
        assert_eq!(j.to_string(), r#"{"s":"hi","x":1}"#);
    }
}
