//! Property-test driver (proptest substitute).
//!
//! Runs a property over N random cases drawn from a seeded [`Rng`]; on
//! failure it reports the iteration's seed so the case replays exactly
//! (re-run with `PROP_SEED=<seed>`), and performs "shrink-lite": it
//! re-runs the generator with progressively smaller size hints to find a
//! smaller failing case.

use super::rng::Rng;

/// Size hint passed to generators; shrinks on failure.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

/// Run `prop(rng, size)` over `cases` random cases.
///
/// `prop` returns `Err(msg)` to fail the property. Panics (with seed and
/// shrink info) on the first failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, Size) -> Result<(), String>,
{
    let base_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xD1CE_5EED);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // size ramps up over the run, like proptest
        let size = Size(4 + (case * 28 / cases.max(1)));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink-lite: same seed, smaller sizes
            let mut smallest: Option<(usize, String)> = None;
            for s in (1..size.0).rev() {
                let mut r2 = Rng::new(seed);
                if let Err(m) = prop(&mut r2, Size(s)) {
                    smallest = Some((s, m));
                }
            }
            match smallest {
                Some((s, m)) => panic!(
                    "property '{name}' failed (case {case}, seed {seed}):\n  at size {}: {msg}\n  shrunk to size {s}: {m}\n  replay: PROP_SEED={base_seed}",
                    size.0
                ),
                None => panic!(
                    "property '{name}' failed (case {case}, seed {seed}, size {}):\n  {msg}\n  replay: PROP_SEED={base_seed}",
                    size.0
                ),
            }
        }
    }
}

/// Assert helper for properties: `prop_assert!(cond, "msg {}", x)?`-style.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert two f32 slices match within tolerance; reports first mismatch.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!(
                "mismatch at {i}: {x} vs {y} (|diff|={} > tol={tol}); {} elements total",
                (x - y).abs(),
                a.len()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |_rng, _size| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng, _| {
            if rng.next_u64() % 2 == 0 {
                Err("even".into())
            } else {
                Err("odd".into())
            }
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
    }

    #[test]
    fn allclose_rejects_different() {
        let e = assert_allclose(&[1.0], &[1.1], 1e-6, 1e-6).unwrap_err();
        assert!(e.contains("mismatch at 0"));
    }

    #[test]
    fn allclose_rejects_length() {
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-6, 1e-6).is_err());
    }
}
