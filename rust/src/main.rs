//! `dbcsr` — launcher CLI for the DBCSR reproduction.
//!
//! Subcommands:
//!   info                      library, artifact and model summary
//!   fig2 [--scale N] [--real] regenerate Fig. 2 (grid configuration)
//!   fig3 [--scale N] [--real] regenerate Fig. 3 (blocked vs densified)
//!   fig4 [--scale N] [--block 4] regenerate Fig. 4 (PDGEMM vs DBCSR)
//!   smm                       regenerate the §II LIBCUSMM speedup curve
//!   autotune [--emit]         run the LIBCUSMM-analog tuner
//!   run --nodes N --rpn R --threads T --block B --shape square|rect
//!       --engine dbcsr|dbcsr-blocked|pdgemm [--scale N] [--real]
//!       [--algorithm layout|auto|cannon|2.5d] [--layers C]
//!       [--transport two-sided|one-sided|one-sided-get] [--overlap]
//!       [--occupancy X] [--iterations N] [--plan-verbose] [--verify]
//!       [--detect-horizon S] [--kill-rank R --kill-at T]
//!                             one experiment point (`auto` picks the
//!                             2.5D replication factor through the
//!                             planner; --occupancy < 1 runs the
//!                             Cannon/2.5D family block-sparse with the
//!                             occupancy-aware planner and the sparse
//!                             wire format; --iterations > 1 runs the
//!                             steady-state pipeline — operands go
//!                             layer-resident once and every iteration
//!                             skips replication and skew;
//!                             --plan-verbose prints the candidate
//!                             table and the achieved occupancies;
//!                             --verify traces the run through the
//!                             comm-protocol checker and exits nonzero
//!                             on any invariant violation;
//!                             --overlap double-buffers the per-tick
//!                             panel shifts (bit-identical results;
//!                             hidden transfer time is reported as
//!                             `overlap hidden`); --detect-horizon sets
//!                             the failure detector's heartbeat horizon
//!                             in virtual seconds (--horizon is the
//!                             deprecated alias);
//!                             --kill-rank/--kill-at inject a rank
//!                             death at slot-tick T — plans with
//!                             replica layers heal it in-run and report
//!                             a `recovery:` line, everything else
//!                             reports Unrecoverable;
//!                             --profile records typed spans on the
//!                             virtual clock and prints the phase
//!                             table, latency percentiles and the
//!                             critical path (runfile key `profile`);
//!                             --trace-out FILE writes the same spans
//!                             as Chrome trace-event JSON, loadable in
//!                             Perfetto / chrome://tracing (profiling
//!                             never changes clocks or results);
//!                             --fault-drop/--fault-dup/--fault-corrupt/
//!                             --fault-delay P (probabilities in [0, 1],
//!                             seeded by --fault-seed S) run the whole
//!                             multiply over an adversarial network —
//!                             the reliability layer retransmits and the
//!                             wasted traffic is reported as `retrans`;
//!                             --fault-policy retry|escalate picks
//!                             between healing and immediate rank death;
//!                             --spares S parks S hot-spare ranks that
//!                             adopt a dead seat between iterations.
//!                             Malformed fault/chaos specs exit with
//!                             code 4 — distinct from verify failures
//!                             (1), usage errors (2) and Unrecoverable
//!                             runs (3))

use dbcsr::bench::figures;
use dbcsr::bench::harness::{run_spec_full, AlgoSpec, Engine, RunSpec, Shape};
use dbcsr::multiply::planner;
use dbcsr::obs::{chrome, ProfileReport};
use dbcsr::bench::table::fmt_secs;
use dbcsr::dist::{verify, FaultPlan, FaultPolicy, NetModel, RunOpts, Transport};
use dbcsr::backend::autotune::{tuned_to_json, Autotuner};
use dbcsr::config::Args;
use dbcsr::matrix::Mode;
use dbcsr::multiply::FaultSpec;
use dbcsr::perfmodel::PerfModel;
use dbcsr::runtime::{artifacts_dir, Manifest};

fn main() {
    let args = Args::parse(std::env::args());
    let scale = args.usize_flag("scale", 1);
    let mode = if args.switch("real") {
        Mode::Real
    } else {
        Mode::Model
    };
    match args.command.as_str() {
        "info" => info(&args),
        "fig2" => {
            for t in figures::fig2(scale, mode) {
                t.print();
            }
        }
        "fig3" => {
            for t in figures::fig3(scale, mode) {
                t.print();
            }
        }
        "fig4" => {
            let blocks: Vec<usize> = match args.flag("block") {
                Some(b) => vec![b.parse().expect("--block integer")],
                None => vec![22, 64],
            };
            for t in figures::fig4(scale, mode, &blocks, args.switch("square-only")) {
                t.print();
            }
        }
        "smm" => figures::smm_speedup().print(),
        "autotune" => autotune(&args),
        "run" => run_one(&args, scale, mode),
        "runfile" => run_file(&args),
        other => {
            eprintln!("unknown subcommand {other:?}; see `dbcsr` source header for usage");
            std::process::exit(2);
        }
    }
}

fn info(args: &Args) {
    println!("dbcsr reproduction v{} — DESIGN.md has the architecture", dbcsr::VERSION);
    let perf = PerfModel::default();
    println!(
        "device model: P100 {:.1} TF/s peak, PCIe {:.1} GB/s, Aries α=1.5µs",
        perf.gpu_peak / 1e12,
        perf.pcie_bw / 1e9
    );
    match Manifest::load(&artifacts_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.variants.len());
            for v in &m.variants {
                print!(
                    "  {:<10} kind={:?} flops={}",
                    v.name,
                    v.kind,
                    v.flops
                );
                if args.switch("kernels") {
                    print!(
                        "  vmem={}KiB mxu_eff={:.3}",
                        v.vmem_bytes / 1024,
                        v.mxu_efficiency
                    );
                }
                println!();
            }
        }
        Err(e) => println!("artifacts: not built ({e}) — run `make artifacts`"),
    }
}

fn autotune(args: &Args) {
    let mut tuner = Autotuner::new(PerfModel::default());
    let sizes: Vec<(usize, usize, usize)> = [4usize, 8, 16, 22, 32, 48, 64, 80]
        .iter()
        .map(|&s| (s, s, s))
        .collect();
    let tuned = tuner.tune(&sizes, 2);
    if args.switch("emit") {
        println!("{}", tuned_to_json(&tuned));
        return;
    }
    println!("{:<8} {:>9} {:>7} {:>7} {:>10} {:>9}", "size", "grouping", "unroll", "pad", "GF/s(est)", "source");
    for t in &tuned {
        println!(
            "{:<8} {:>9} {:>7} {:>7} {:>10.0} {:>9}",
            format!("{}x{}x{}", t.m, t.n, t.k),
            t.params.grouping,
            t.params.unroll,
            t.params.pad_m,
            t.gflops,
            if t.measured { "measured" } else { "model" }
        );
    }
}

/// `dbcsr runfile experiment.conf` — run every experiment point listed
/// in a config file (see configs/*.conf). Sections define points; global
/// keys set defaults; perf.* keys override the device model.
fn run_file(args: &Args) {
    use dbcsr::config::ConfigFile;
    let path = args
        .positional
        .first()
        .expect("usage: dbcsr runfile <config file>");
    let cf = ConfigFile::load(path).expect("readable config file");
    // collect section names (keys of the form "<section>.<field>")
    let mut sections: Vec<String> = cf
        .values
        .keys()
        .filter_map(|k| k.split_once('.').map(|(s, _)| s.to_string()))
        .filter(|s| s != "perf" && s != "defaults")
        .collect();
    sections.dedup();
    let get = |section: &str, key: &str, def: usize| -> usize {
        cf.usize_or(&format!("{section}.{key}"), cf.usize_or(&format!("defaults.{key}"), def))
    };
    let get_s = |section: &str, key: &str, def: &str| -> String {
        cf.get(&format!("{section}.{key}"))
            .or_else(|| cf.get(&format!("defaults.{key}")))
            .unwrap_or(def)
            .to_string()
    };
    println!("runfile {path}: {} experiment points\n", sections.len());
    for section in &sections {
        let shape = match get_s(section, "shape", "square").as_str() {
            "rect" => Shape::paper_rect(),
            _ => Shape::paper_square(),
        }
        .scaled(get(section, "scale", 1));
        let engine = match get_s(section, "engine", "dbcsr").as_str() {
            "dbcsr-blocked" => Engine::DbcsrBlocked,
            "pdgemm" => Engine::Pdgemm,
            _ => Engine::DbcsrDensified,
        };
        let rpn = get(section, "rpn", 4);
        // chaos keys mirror the CLI flags: fault-seed, fault-drop/dup/
        // corrupt/delay, fault-policy, spares (section or defaults scope)
        let (faultnet, fault_policy, spares) = parse_chaos(&|key| {
            cf.get(&format!("{section}.{key}"))
                .or_else(|| cf.get(&format!("defaults.{key}")))
                .map(String::from)
        });
        let iterations = get(section, "iterations", 1);
        if spares > 0 && iterations <= 1 {
            fault_spec_error(format!(
                "[{section}] spares = {spares} needs iterations > 1: only a \
                 steady-state resident session can splice a spare into a dead seat"
            ));
        }
        let spec = RunSpec {
            nodes: get(section, "nodes", 1),
            rpn,
            threads: get(section, "threads", 3),
            block: get(section, "block", 22),
            shape,
            engine,
            mode: if get_s(section, "mode", "model") == "real" {
                Mode::Real
            } else {
                Mode::Model
            },
            net: match get_s(section, "net", "aries").as_str() {
                "aries" => NetModel::aries(rpn),
                "ideal" => NetModel::ideal(),
                other => panic!("net = aries|ideal, got {other:?}"),
            },
            transport: match get_s(section, "transport", "two-sided").as_str() {
                "two-sided" => Transport::TwoSided,
                "one-sided" => Transport::OneSided,
                "one-sided-get" => Transport::OneSidedGet,
                other => {
                    panic!("transport = two-sided|one-sided|one-sided-get, got {other:?}")
                }
            },
            overlap: get_s(section, "overlap", "false") == "true",
            algo: match get_s(section, "algorithm", "layout").as_str() {
                "layout" => AlgoSpec::Layout,
                "auto" => AlgoSpec::Auto,
                "cannon" => AlgoSpec::Cannon,
                "2.5d" => AlgoSpec::TwoFiveD {
                    layers: get(section, "layers", 2),
                },
                other => panic!("algorithm = layout|auto|cannon|2.5d, got {other:?}"),
            },
            plan_verbose: false,
            occupancy: cf
                .get(&format!("{section}.occupancy"))
                .or_else(|| cf.get("defaults.occupancy"))
                .map(|v| {
                    let occ = v
                        .parse::<f64>()
                        .expect("occupancy must be a float in (0, 1]");
                    assert!(
                        occ > 0.0 && occ <= 1.0,
                        "occupancy must be in (0, 1], got {occ}"
                    );
                    occ
                })
                .unwrap_or(1.0),
            iterations,
            // fault = <rank>@<tick> injects a rank death mid-multiply
            fault: cf
                .get(&format!("{section}.fault"))
                .or_else(|| cf.get("defaults.fault"))
                .map(|v| parse_fault(v).unwrap_or_else(fault_spec_error)),
            faultnet,
            fault_policy,
            spares,
        };
        // `detect-horizon` (seconds) tunes the failure detector; the
        // pre-rename `horizon` key is kept as a deprecated alias
        let detect_horizon = cf
            .get(&format!("{section}.detect-horizon"))
            .or_else(|| cf.get(&format!("{section}.horizon")))
            .or_else(|| cf.get("defaults.detect-horizon"))
            .or_else(|| cf.get("defaults.horizon"))
            .map(|v| v.parse::<f64>().expect("detect-horizon must be seconds (float)"))
            .unwrap_or_else(|| RunOpts::default().detect_horizon);
        let profiling = get_s(section, "profile", "false") == "true";
        let (r, _, prof) = run_spec_full(
            spec,
            RunOpts {
                profile: profiling,
                detect_horizon,
                ..RunOpts::default()
            },
        );
        if let Some(prof) = &prof {
            print!("[{section}] profile:\n{}", ProfileReport::build(prof).render());
        }
        if r.unrecoverable {
            println!(
                "[{section}] recovery: Unrecoverable — fault injected but the \
                 resolved plan has no replica layer; a death there means restart"
            );
            continue;
        }
        println!(
            "[{section}] {}{} (stacks {}, comm {:.1} MiB, meta {:.2} MiB{}{}{}{}{})",
            fmt_secs(r.seconds),
            if r.iterations > 1 {
                format!(" / {} iters + setup {}", r.iterations, fmt_secs(r.repl_seconds))
            } else {
                String::new()
            },
            r.stats.stacks,
            r.stats.comm_bytes as f64 / (1 << 20) as f64,
            r.meta_bytes as f64 / (1 << 20) as f64,
            if r.overlap_hidden_seconds > 0.0 {
                format!(", overlap hidden {:.3}s", r.overlap_hidden_seconds)
            } else {
                String::new()
            },
            if r.stats.a_total_blocks > 0 && (r.occupancy_a < 1.0 || r.occupancy_b < 1.0) {
                format!(
                    ", occ A {:.4} B {:.4} C {:.4}",
                    r.occupancy_a, r.occupancy_b, r.occupancy_c
                )
            } else {
                String::new()
            },
            if r.recovery_bytes > 0 {
                format!(
                    ", recovery {:.1} MiB / {:.3}s",
                    r.recovery_bytes as f64 / (1 << 20) as f64,
                    r.recovery_seconds
                )
            } else {
                String::new()
            },
            if r.retrans_bytes > 0 {
                format!(
                    ", retrans {:.1} MiB / {:.3}s",
                    r.retrans_bytes as f64 / (1 << 20) as f64,
                    r.retrans_seconds
                )
            } else {
                String::new()
            },
            if r.oom { ", OOM" } else { "" }
        );
    }
}

/// Exit code 4: a malformed fault/chaos specification. These are user
/// errors in the injection surface, not library bugs — report the exact
/// token that failed and exit with a code harness scripts can branch on
/// (distinct from verify failures, usage errors and Unrecoverable runs).
fn fault_spec_error(msg: String) -> ! {
    eprintln!("fault spec error: {msg}");
    std::process::exit(4);
}

/// `<rank>@<tick>` — the runfile `fault` key and the CLI's
/// `--kill-rank R --kill-at T` in one compact form. Every malformed
/// shape comes back as a typed error naming the offending token.
fn parse_fault(v: &str) -> Result<FaultSpec, String> {
    let (r, t) = v
        .split_once('@')
        .ok_or_else(|| format!("fault must be <rank>@<slot-tick>, got {v:?}"))?;
    Ok(FaultSpec {
        rank: r
            .trim()
            .parse()
            .map_err(|_| format!("fault rank must be an integer, got {:?}", r.trim()))?,
        at_tick: t
            .trim()
            .parse()
            .map_err(|_| format!("fault slot-tick must be an integer, got {:?}", t.trim()))?,
    })
}

/// The chaos knobs shared by `run` flags and runfile keys: a seeded
/// wire-fault plan, the reliability policy and the hot-spare pool size.
/// `get` abstracts over `--fault-drop 0.01` vs `fault-drop = 0.01`; any
/// malformed value exits 4 through [`fault_spec_error`].
fn parse_chaos(get: &dyn Fn(&str) -> Option<String>) -> (Option<FaultPlan>, FaultPolicy, usize) {
    let rate = |key: &str| -> f64 {
        get(key).map_or(0.0, |v| match v.parse::<f64>() {
            Ok(p) if (0.0..=1.0).contains(&p) => p,
            Ok(p) => fault_spec_error(format!("{key} must be a probability in [0, 1], got {p}")),
            Err(_) => fault_spec_error(format!("{key} must be a float in [0, 1], got {v:?}")),
        })
    };
    let plan = FaultPlan {
        seed: get("fault-seed").map_or(FaultPlan::default().seed, |v| {
            v.parse()
                .unwrap_or_else(|_| fault_spec_error(format!("fault-seed must be an integer, got {v:?}")))
        }),
        drop: rate("fault-drop"),
        dup: rate("fault-dup"),
        corrupt: rate("fault-corrupt"),
        delay: rate("fault-delay"),
    };
    let policy = get("fault-policy").map_or(FaultPolicy::Retry, |v| match v.as_str() {
        "retry" => FaultPolicy::Retry,
        "escalate" => FaultPolicy::Escalate,
        other => fault_spec_error(format!("fault-policy must be retry|escalate, got {other:?}")),
    });
    let spares = get("spares").map_or(0, |v| {
        v.parse()
            .unwrap_or_else(|_| fault_spec_error(format!("spares must be an integer, got {v:?}")))
    });
    (plan.is_active().then_some(plan), policy, spares)
}

fn run_one(args: &Args, scale: usize, mode: Mode) {
    let shape = match args.str_flag("shape", "square") {
        "square" => Shape::paper_square().scaled(scale),
        "rect" => Shape::paper_rect().scaled(scale),
        other => panic!("--shape square|rect, got {other:?}"),
    };
    let engine = match args.str_flag("engine", "dbcsr") {
        "dbcsr" => Engine::DbcsrDensified,
        "dbcsr-blocked" => Engine::DbcsrBlocked,
        "pdgemm" => Engine::Pdgemm,
        other => panic!("--engine dbcsr|dbcsr-blocked|pdgemm, got {other:?}"),
    };
    let rpn = args.usize_flag("rpn", 4);
    let net = match args.str_flag("net", "aries") {
        "aries" => NetModel::aries(rpn),
        "ideal" => NetModel::ideal(),
        other => panic!("--net aries|ideal, got {other:?}"),
    };
    let transport = match args.str_flag("transport", "two-sided") {
        "two-sided" => Transport::TwoSided,
        "one-sided" => Transport::OneSided,
        "one-sided-get" => Transport::OneSidedGet,
        other => panic!("--transport two-sided|one-sided|one-sided-get, got {other:?}"),
    };
    // default preserves the pre-planner behavior (rect → tall-skinny,
    // square → Cannon); `--algorithm auto` opts into the planner, which
    // prices the Cannon/2.5D family only
    let algo = match args.str_flag("algorithm", "layout") {
        "auto" => AlgoSpec::Auto,
        "layout" => AlgoSpec::Layout,
        "cannon" => AlgoSpec::Cannon,
        "2.5d" | "twofive" => AlgoSpec::TwoFiveD {
            layers: args.usize_flag("layers", 2),
        },
        other => panic!("--algorithm auto|layout|cannon|2.5d, got {other:?}"),
    };
    let occupancy = args
        .flag("occupancy")
        .map(|v| v.parse::<f64>().expect("--occupancy must be a float in (0, 1]"))
        .unwrap_or(1.0);
    assert!(
        occupancy > 0.0 && occupancy <= 1.0,
        "--occupancy must be in (0, 1], got {occupancy}"
    );
    let fault = args.flag("kill-rank").map(|r| FaultSpec {
        rank: r.parse().unwrap_or_else(|_| {
            fault_spec_error(format!("--kill-rank must be a rank index, got {r:?}"))
        }),
        at_tick: args
            .try_usize_flag("kill-at", 0)
            .unwrap_or_else(fault_spec_error),
    });
    if args.flag("kill-at").is_some() && fault.is_none() {
        fault_spec_error("--kill-at needs --kill-rank to name the victim".to_string());
    }
    // flag names match the runfile keys one for one: --fault-seed,
    // --fault-drop/dup/corrupt/delay, --fault-policy, --spares
    let (faultnet, fault_policy, spares) =
        parse_chaos(&|key| args.flag(key).map(String::from));
    let iterations = args.usize_flag("iterations", 1);
    if spares > 0 && iterations <= 1 {
        fault_spec_error(format!(
            "--spares {spares} needs --iterations > 1: only a steady-state \
             resident session can splice a spare into a dead seat"
        ));
    }
    let spec = RunSpec {
        nodes: args.usize_flag("nodes", 1),
        rpn,
        threads: args.usize_flag("threads", 3),
        block: args.usize_flag("block", 22),
        shape,
        engine,
        mode,
        net,
        transport,
        overlap: args.switch("overlap"),
        algo,
        plan_verbose: args.switch("plan-verbose"),
        occupancy,
        iterations,
        fault,
        faultnet,
        fault_policy,
        spares,
    };
    println!("spec: {spec:?}");
    if spec.plan_verbose && engine != Engine::Pdgemm {
        let plan = planner::choose_plan(&spec.plan_input());
        println!(
            "planner candidates ({} ranks, {:?}, block {}, {} transport):",
            spec.nodes * spec.rpn,
            spec.shape.dims(),
            spec.block,
            spec.transport,
        );
        print!("{}", plan.render());
        if algo != AlgoSpec::Auto {
            println!("(informational — --algorithm {algo:?} overrides the planner)");
        }
    }
    // --detect-horizon (seconds) tunes the failure detector; --horizon
    // is the pre-rename deprecated alias
    let detect_horizon = args
        .flag("detect-horizon")
        .or_else(|| {
            let old = args.flag("horizon");
            if old.is_some() {
                eprintln!("note: --horizon is deprecated, use --detect-horizon");
            }
            old
        })
        .map(|v| v.parse::<f64>().expect("--detect-horizon must be seconds (float)"))
        .unwrap_or_else(|| RunOpts::default().detect_horizon);
    let verifying = args.switch("verify");
    let trace_out = args.flag("trace-out").map(String::from);
    let profiling = args.switch("profile") || trace_out.is_some();
    let (r, trace, prof) = run_spec_full(
        spec,
        RunOpts {
            trace: verifying,
            profile: profiling,
            detect_horizon,
            ..RunOpts::default()
        },
    );
    if verifying {
        let report = verify::check(&trace.expect("traced run must return a trace"));
        print!("{}", report.render());
        if !report.is_clean() {
            std::process::exit(1);
        }
    }
    if r.unrecoverable {
        println!(
            "recovery: Unrecoverable — rank {} would die with no replica layer \
             to heal from (the resolved plan has c = 1); run with --algorithm \
             2.5d --layers 2 (or auto) or restart from scratch",
            spec.fault.map(|f| f.rank).unwrap_or(0),
        );
        std::process::exit(3);
    }
    if let Some(f) = spec.fault {
        println!(
            "recovery: healed the death of rank {} (slot-tick {}) in-run — \
             {:.1} MiB replica fetches, {:.3}s recovery time",
            f.rank,
            f.at_tick,
            r.recovery_bytes as f64 / (1 << 20) as f64,
            r.recovery_seconds,
        );
    }
    if let Some(plan) = &r.plan {
        println!(
            "plan: {} {}x{}x{} (source {}, replication {}, horizon {}, predicted {})",
            plan.algorithm,
            plan.rows,
            plan.cols,
            plan.layers,
            plan.source,
            if plan.charged_replication {
                "charged"
            } else {
                "amortized"
            },
            plan.horizon,
            fmt_secs(plan.predicted_seconds),
        );
    }
    println!(
        "virtual time {}{}{}   (sim wallclock {:.2}s)",
        fmt_secs(r.seconds),
        if r.iterations > 1 {
            format!(" over {} iterations", r.iterations)
        } else {
            String::new()
        },
        if r.repl_seconds > 0.0 {
            format!(" + one-time residency setup {}", fmt_secs(r.repl_seconds))
        } else {
            String::new()
        },
        r.wall,
    );
    println!(
        "stacks {}  block_mults {}  flops {:.3e}  comm {:.1} MiB in {} msgs (wait {:.3}s{}{}, meta {:.2} MiB)  densify {:.1} MiB  dev peak {:.2} GiB{}",
        r.stats.stacks,
        r.stats.block_mults,
        r.stats.flops as f64,
        r.stats.comm_bytes as f64 / (1 << 20) as f64,
        r.stats.comm_msgs,
        r.stats.comm_wait_s,
        if r.stats.overlap_hidden_s > 0.0 {
            format!(", overlap hidden {:.3}s", r.stats.overlap_hidden_s)
        } else {
            String::new()
        },
        if r.retrans_bytes > 0 {
            // retransmitted traffic is wasted wire time, disjoint from
            // the goodput counted in `comm`
            format!(
                ", retrans {:.1} MiB / {:.3}s",
                r.retrans_bytes as f64 / (1 << 20) as f64,
                r.retrans_seconds
            )
        } else {
            String::new()
        },
        r.stats.meta_bytes as f64 / (1 << 20) as f64,
        r.stats.densify_bytes as f64 / (1 << 20) as f64,
        r.stats.dev_mem_peak as f64 / (1 << 30) as f64,
        match (r.stats.overlap_downgraded, r.oom) {
            (true, true) => "  (overlap downgraded: faults force synchronous shifts)  ** OOM **",
            (true, false) => "  (overlap downgraded: faults force synchronous shifts)",
            (false, true) => "  ** OOM **",
            (false, false) => "",
        }
    );
    if r.stats.a_total_blocks > 0
        && (r.occupancy_a < 1.0 || r.occupancy_b < 1.0 || r.stats.filtered_blocks > 0)
    {
        println!(
            "occupancy A {:.4} B {:.4} -> C {:.4}  ({} result blocks filtered)",
            r.occupancy_a, r.occupancy_b, r.occupancy_c, r.stats.filtered_blocks
        );
    }
    if let Some(prof) = &prof {
        if let Some(path) = &trace_out {
            let json = chrome::chrome_trace(prof);
            std::fs::write(path, json.to_string()).unwrap_or_else(|e| {
                eprintln!("cannot write {path}: {e}");
                std::process::exit(2);
            });
            println!(
                "trace: {} spans -> {path} (load in Perfetto / chrome://tracing)",
                prof.spans.len()
            );
        }
        if args.switch("profile") {
            print!("{}", ProfileReport::build(prof).render());
        }
    }
}
