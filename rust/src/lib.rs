//! # DBCSR reproduction — distributed dense matrix multiplication
//!
//! A rust + JAX + Pallas reproduction of *"DBCSR: A Library for Dense
//! Matrix Multiplications on Distributed GPU-Accelerated Systems"*
//! (Sivkov, Lazzaro, Hutter — 2019).
//!
//! The crate implements the full DBCSR multiplication pipeline — blocked-CSR
//! matrices on a 2-D rank grid, Cannon and tall-and-skinny data exchange,
//! the Traversal/Generation/Scheduler local engine, and the paper's
//! **densification** optimization — together with every substrate the paper
//! runs on (an MPI-like comm layer, a GPU device model, a cuBLAS-analog AOT
//! Pallas GEMM executed through PJRT, a LIBCUSMM-analog autotuner) and the
//! ScaLAPACK-style PDGEMM baseline it compares against.
//!
//! See `DESIGN.md` for the architecture and the paper→testbed substitution
//! table, and `EXPERIMENTS.md` for the regenerated figures.

pub mod backend;
pub mod bench;
pub mod config;
pub mod dist;
pub mod linalg;
pub mod matrix;
pub mod multiply;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod scalapack;
pub mod util;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
