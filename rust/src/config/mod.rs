//! Configuration: CLI argument parsing and experiment configuration files
//! (clap/serde are not in the offline crate set — DESIGN.md §3).
//!
//! `Args` is a small `--flag value` / `--switch` parser; `ConfigFile`
//! reads a `key = value` file (a TOML subset: comments, sections ignored)
//! so experiment sweeps can be captured in version-controlled configs.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positionals, flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()`-style iterator (program name first).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut out = Args {
            command: it.next().unwrap_or_default(),
            ..Default::default()
        };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --flag value | --flag=value | --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn switch(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// Like [`Args::usize_flag`] but returns the parse failure instead of
    /// panicking — the fault-injection surface owns an exit-code contract
    /// (malformed fault specs exit 4, not via an opaque panic) and needs
    /// the error as a value.
    pub fn try_usize_flag(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn str_flag<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }
}

/// `key = value` config file (TOML subset; `#` comments; sections `[x]`
/// flatten into `x.key`).
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    pub values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> ConfigFile {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for line in text.lines() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                values.insert(key, v.trim().trim_matches('"').to_string());
            }
        }
        ConfigFile { values }
    }

    pub fn load(path: &str) -> std::io::Result<ConfigFile> {
        Ok(ConfigFile::parse(&std::fs::read_to_string(path)?))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(argv("dbcsr fig2 --scale 4 --real --block=22 extra"));
        assert_eq!(a.command, "fig2");
        assert_eq!(a.usize_flag("scale", 1), 4);
        assert!(a.switch("real"));
        assert_eq!(a.flag("block"), Some("22"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_flags_default() {
        let a = Args::parse(argv("dbcsr run"));
        assert_eq!(a.usize_flag("nodes", 7), 7);
        assert!(!a.switch("real"));
        assert_eq!(a.str_flag("engine", "dbcsr"), "dbcsr");
    }

    #[test]
    fn try_usize_flag_is_typed() {
        let a = Args::parse(argv("dbcsr run --kill-at twelve --kill-rank 3"));
        assert_eq!(a.try_usize_flag("kill-rank", 0), Ok(3));
        assert_eq!(a.try_usize_flag("missing", 7), Ok(7));
        let e = a.try_usize_flag("kill-at", 0).unwrap_err();
        assert!(e.contains("kill-at") && e.contains("twelve"), "{e}");
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(argv("dbcsr fig4 --real"));
        assert!(a.switch("real"));
    }

    #[test]
    fn config_file_sections() {
        let c = ConfigFile::parse("# comment\nscale = 2\n[perf]\ngpu_peak = 4.7e12\nname = \"x\"\n");
        assert_eq!(c.usize_or("scale", 1), 2);
        assert_eq!(c.f64_or("perf.gpu_peak", 0.0), 4.7e12);
        assert_eq!(c.get("perf.name"), Some("x"));
        assert_eq!(c.usize_or("missing", 9), 9);
    }
}
