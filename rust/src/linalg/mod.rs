//! Linear-algebra methods built on the multiplication kernel (§II: "the
//! library includes some linear algebra methods: the Arnoldi eigensolver,
//! the matrix sign, the matrix inverse, p-root and exponential
//! algorithms" — the CP2K linear-scaling-SCF toolbox of paper ref.\[1\]).
//!
//! Every method here is a *consumer* of the public multiply API — the way
//! CP2K consumes DBCSR — which makes this module both a deliverable and a
//! continuous integration test of the multiplication semantics:
//!
//! * [`matrix_sign`] — Newton–Schulz iteration `Xₖ₊₁ = ½Xₖ(3I − Xₖ²)`;
//! * [`matrix_inverse`] — Newton–Hotelling `Xₖ₊₁ = Xₖ(2I − A·Xₖ)`;
//! * [`matrix_exp`] — scaling-and-squaring with a Taylor core;
//! * [`matvec`] / [`arnoldi_extremal_eigs`] — distributed matrix-vector
//!   products and an Arnoldi/Lanczos-style extremal-eigenvalue estimator
//!   (used by the sign/inverse methods to bound spectra for scaling).

use crate::backend::gpu_sim::DeviceOom;
use crate::dist::{CommView, Grid2D, Payload};
use crate::matrix::matrix::Fill;
use crate::matrix::{DistMatrix, Mode};
use crate::multiply::{multiply, MultiplyConfig};

/// `C = A·B` through the configured pipeline (thin wrapper used below).
fn mm(grid: &Grid2D, a: &DistMatrix, b: &DistMatrix, cfg: &MultiplyConfig) -> Result<DistMatrix, DeviceOom> {
    Ok(multiply(grid, a, b, cfg)?.c)
}

/// Distributed identity with the same layout/distribution as `like`.
pub fn identity_like(like: &DistMatrix) -> DistMatrix {
    assert_eq!(like.rows.dim, like.cols.dim, "identity needs square");
    let mut m = DistMatrix::dense(
        like.rows.clone(),
        like.cols.clone(),
        like.row_dist.clone(),
        like.col_dist.clone(),
        like.coords,
        like.mode,
        Fill::Zero,
    );
    if m.mode == Mode::Real {
        let blocks: Vec<(usize, usize, usize, usize)> = m
            .local
            .iter_nnz()
            .map(|(b, r, c)| (b, r, c, m.local.area_of(r, c)))
            .collect();
        for (b, r, c, area) in blocks {
            let (gi, gj) = (m.local.row_ids[r], m.local.col_ids[c]);
            if gi != gj {
                continue;
            }
            let cs = m.local.col_sizes[c];
            let rs = m.local.row_sizes[r];
            let blk = m.local.store.block_mut(b, area);
            for i in 0..rs.min(cs) {
                blk[i * cs + i] = 1.0;
            }
        }
    }
    m
}

/// Matrix sign function via Newton–Schulz: `Xₖ₊₁ = ½ Xₖ (3I − Xₖ²)`.
///
/// Converges quadratically for matrices with `‖I − A²‖ < 1`; callers
/// pre-scale by the spectral bound (see [`arnoldi_extremal_eigs`]).
/// Returns (sign(A), iterations used).
pub fn matrix_sign(
    grid: &Grid2D,
    a: &DistMatrix,
    cfg: &MultiplyConfig,
    max_iter: usize,
    tol: f32,
) -> Result<(DistMatrix, usize), DeviceOom> {
    let id = identity_like(a);
    let mut x = a.clone();
    for it in 0..max_iter {
        // X² ; then Y = 3I − X²; then X ← ½ X Y
        let x2 = mm(grid, &x, &x, cfg)?;
        let mut y = id.clone();
        y.scale(3.0);
        y.add_scaled(&x2, -1.0);
        let mut next = mm(grid, &x, &y, cfg)?;
        next.scale(0.5);
        // convergence: ‖X² − I‖_F (reuse x2)
        let mut resid = x2.clone();
        resid.add_scaled(&id, -1.0);
        let err = resid.frobenius_sq(&grid.world).sqrt();
        x = next;
        if err < tol {
            return Ok((x, it + 1));
        }
    }
    Ok((x, max_iter))
}

/// Newton–Hotelling inverse: `Xₖ₊₁ = Xₖ (2I − A Xₖ)`, seeded with
/// `X₀ = αAᵀ ≈ A⁻¹` (α = 1/‖A‖² estimate from `‖A‖_F`).
pub fn matrix_inverse(
    grid: &Grid2D,
    a: &DistMatrix,
    cfg: &MultiplyConfig,
    max_iter: usize,
    tol: f32,
) -> Result<(DistMatrix, usize), DeviceOom> {
    let id = identity_like(a);
    // X0 = A^T / ||A||_F^2 — convergent for any nonsingular A when the
    // condition number is moderate (our tests use diagonally-dominant A)
    let fro2 = a.frobenius_sq(&grid.world);
    let mut x = crate::matrix::ops::transpose(a, &grid.world, (grid.rows, grid.cols));
    x.scale(1.0 / fro2);
    for it in 0..max_iter {
        let ax = mm(grid, a, &x, cfg)?;
        let mut y = id.clone();
        y.scale(2.0);
        y.add_scaled(&ax, -1.0);
        let next = mm(grid, &x, &y, cfg)?;
        // residual ‖A·X − I‖
        let mut resid = ax;
        resid.add_scaled(&id, -1.0);
        let err = resid.frobenius_sq(&grid.world).sqrt();
        x = next;
        if err < tol {
            return Ok((x, it + 1));
        }
    }
    Ok((x, max_iter))
}

/// Matrix exponential by scaling-and-squaring: `exp(A) = (exp(A/2ˢ))^(2ˢ)`
/// with an order-`taylor` Taylor core.
pub fn matrix_exp(
    grid: &Grid2D,
    a: &DistMatrix,
    cfg: &MultiplyConfig,
    taylor: usize,
) -> Result<DistMatrix, DeviceOom> {
    // pick s so ‖A/2^s‖_F ≲ 0.5
    let norm = a.frobenius_sq(&grid.world).sqrt();
    let s = norm.max(1e-30).log2().ceil().max(0.0) as u32 + 1;
    let mut small = a.clone();
    small.scale(1.0 / (1u64 << s) as f32);

    // Taylor: E = I + X (I/1! + X/2! (I + ...)) — Horner form
    let id = identity_like(a);
    let mut e = id.clone();
    for j in (1..=taylor).rev() {
        // e ← I + (X · e) / j
        let xe = mm(grid, &small, &e, cfg)?;
        e = id.clone();
        e.add_scaled(&xe, 1.0 / j as f32);
    }
    // square s times
    for _ in 0..s {
        e = mm(grid, &e, &e, cfg)?;
    }
    Ok(e)
}

/// Distributed matrix-vector product `y = A·x` with `x` replicated on
/// every rank (length = global cols). Collective.
pub fn matvec(a: &DistMatrix, x: &[f32], world: &CommView) -> Vec<f32> {
    assert_eq!(a.mode, Mode::Real);
    let (m, n) = a.global_dims();
    assert_eq!(x.len(), n);
    let mut local = vec![0.0f32; m];
    for (b, r, c) in a.local.iter_nnz() {
        let (gi, gj) = (a.local.row_ids[r], a.local.col_ids[c]);
        let (rs, cs) = (a.local.row_sizes[r], a.local.col_sizes[c]);
        let (r0, c0) = (a.rows.block_start(gi), a.cols.block_start(gj));
        let blk = a.local.store.block(b, rs * cs);
        for i in 0..rs {
            let mut acc = 0.0f32;
            for j in 0..cs {
                acc += blk[i * cs + j] * x[c0 + j];
            }
            local[r0 + i] += acc;
        }
    }
    world.allreduce_sum_f32(Payload::F32(local)).into_f32()
}

/// Arnoldi (symmetric: Lanczos-like) extremal-eigenvalue estimate via
/// power-type iteration with Rayleigh quotients over `iters` steps.
/// Returns (λ_max estimate, final Rayleigh residual).
pub fn arnoldi_extremal_eigs(
    a: &DistMatrix,
    world: &CommView,
    iters: usize,
    seed: u64,
) -> (f32, f32) {
    let (_, n) = a.global_dims();
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v: Vec<f32> = (0..n).map(|_| rng.next_f32_sym()).collect();
    normalize(&mut v);
    let mut lambda = 0.0f32;
    let mut resid = f32::INFINITY;
    for _ in 0..iters {
        let w = matvec(a, &v, world);
        lambda = dot(&v, &w); // Rayleigh quotient (v normalized)
        // residual ‖Av − λv‖
        resid = w
            .iter()
            .zip(v.iter())
            .map(|(wi, vi)| (wi - lambda * vi).powi(2))
            .sum::<f32>()
            .sqrt();
        v = w;
        normalize(&mut v);
    }
    (lambda, resid)
}

fn normalize(v: &mut [f32]) {
    let n = dot(v, v).sqrt().max(1e-30);
    for x in v.iter_mut() {
        *x /= n;
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{run_ranks, NetModel};
    use crate::matrix::{BlockLayout, Distribution};

    /// Well-conditioned symmetric test matrix: D + εR with dominant
    /// diagonal, distributed on a 2×2 grid.
    fn test_matrix(coords: (usize, usize), n: usize, block: usize, eps: f32) -> DistMatrix {
        let mut a = DistMatrix::dense(
            BlockLayout::new(n, block),
            BlockLayout::new(n, block),
            Distribution::cyclic(2),
            Distribution::cyclic(2),
            coords,
            Mode::Real,
            Fill::Random { seed: 300 },
        );
        // symmetrize-ish + diagonal dominance: A = εR + 2I-ish diag
        a.scale(eps);
        let blocks: Vec<(usize, usize, usize, usize)> = a
            .local
            .iter_nnz()
            .map(|(b, r, c)| (b, r, c, a.local.area_of(r, c)))
            .collect();
        for (b, r, c, area) in blocks {
            let (gi, gj) = (a.local.row_ids[r], a.local.col_ids[c]);
            if gi != gj {
                continue;
            }
            let cs = a.local.col_sizes[c];
            let rs = a.local.row_sizes[r];
            let blk = a.local.store.block_mut(b, area);
            for i in 0..rs.min(cs) {
                blk[i * cs + i] += 1.0;
            }
        }
        a
    }

    #[test]
    fn identity_like_is_identity() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.0);
            let id = identity_like(&a);
            id.trace(&grid.world)
        });
        assert!((out[0] - 24.0).abs() < 1e-4);
    }

    #[test]
    fn sign_of_spd_matrix_is_identity() {
        // A ≈ I + εR has positive spectrum → sign(A) = I
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.05);
            let cfg = MultiplyConfig::default();
            let (s, iters) = matrix_sign(&grid, &a, &cfg, 30, 1e-4).unwrap();
            let mut diff = s.clone();
            diff.add_scaled(&identity_like(&s), -1.0);
            (diff.frobenius_sq(&grid.world).sqrt(), iters)
        });
        let (err, iters) = out[0];
        assert!(err < 1e-2, "‖sign(A) − I‖ = {err} after {iters} iters");
        assert!(iters < 30, "should converge");
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.05);
            let cfg = MultiplyConfig::default();
            let (inv, iters) = matrix_inverse(&grid, &a, &cfg, 50, 1e-4).unwrap();
            let ax = multiply(&grid, &a, &inv, &cfg).unwrap().c;
            let mut diff = ax;
            diff.add_scaled(&identity_like(&a), -1.0);
            (diff.frobenius_sq(&grid.world).sqrt(), iters)
        });
        let (err, iters) = out[0];
        assert!(err < 1e-2, "‖A·A⁻¹ − I‖ = {err} after {iters} iters");
    }

    #[test]
    fn exp_of_zero_is_identity() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 16, 4, 0.0);
            let mut z = a.clone();
            z.scale(0.0);
            // zero out diagonal too: build from Fill::Zero directly
            let z = DistMatrix::dense(
                z.rows.clone(),
                z.cols.clone(),
                z.row_dist.clone(),
                z.col_dist.clone(),
                z.coords,
                Mode::Real,
                Fill::Zero,
            );
            let cfg = MultiplyConfig::default();
            let e = matrix_exp(&grid, &z, &cfg, 8).unwrap();
            let mut diff = e;
            diff.add_scaled(&identity_like(&a), -1.0);
            diff.frobenius_sq(&grid.world).sqrt()
        });
        assert!(out[0] < 1e-4, "exp(0) ≠ I: {}", out[0]);
    }

    #[test]
    fn exp_trace_matches_scalar_exp_for_diagonal() {
        // A = c·I → exp(A) = e^c·I, trace = n·e^c
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let base = test_matrix(grid.coords(), 16, 4, 0.0); // I
            let mut a = base.clone();
            a.scale(0.5); // A = 0.5 I
            let cfg = MultiplyConfig::default();
            let e = matrix_exp(&grid, &a, &cfg, 10).unwrap();
            e.trace(&grid.world)
        });
        let want = 16.0 * 0.5f32.exp();
        assert!((out[0] - want).abs() / want < 1e-3, "{} vs {want}", out[0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 20, 5, 0.3);
            let x: Vec<f32> = (0..20).map(|i| (i as f32 * 0.37).sin()).collect();
            let y = matvec(&a, &x, &grid.world);
            let mut dense = vec![0.0f32; 20 * 20];
            a.add_into_dense(&mut dense);
            (y, dense)
        });
        // reconstruct global dense from all ranks
        let mut full = vec![0.0f32; 400];
        for (_, d) in &out {
            for (f, x) in full.iter_mut().zip(d.iter()) {
                *f += x;
            }
        }
        let x: Vec<f32> = (0..20).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut want = vec![0.0f32; 20];
        for i in 0..20 {
            for j in 0..20 {
                want[i] += full[i * 20 + j] * x[j];
            }
        }
        // ranks each computed a PARTIAL dense view but matvec allreduced:
        // y should equal full matvec on every rank
        for (yi, wi) in out[0].0.iter().zip(want.iter()) {
            assert!((yi - wi).abs() < 1e-3, "{yi} vs {wi}");
        }
    }

    #[test]
    fn arnoldi_finds_dominant_eigenvalue() {
        // A = I + 0.05 R: spectrum clustered near 1; λ_max slightly above
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.05);
            arnoldi_extremal_eigs(&a, &grid.world, 40, 5)
        });
        let (lambda, resid) = out[0];
        assert!((0.8..1.6).contains(&lambda), "λ={lambda}");
        assert!(resid < 0.2, "residual {resid}");
    }
}
