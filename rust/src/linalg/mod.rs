//! Linear-algebra methods built on the multiplication kernel (§II: "the
//! library includes some linear algebra methods: the Arnoldi eigensolver,
//! the matrix sign, the matrix inverse, p-root and exponential
//! algorithms" — the CP2K linear-scaling-SCF toolbox of paper ref.\[1\]).
//!
//! Every method here is a *consumer* of the public multiply API — the way
//! CP2K consumes DBCSR — which makes this module both a deliverable and a
//! continuous integration test of the multiplication semantics:
//!
//! * [`matrix_sign`] — Newton–Schulz iteration `Xₖ₊₁ = ½Xₖ(3I − Xₖ²)`;
//! * [`matrix_inverse`] — Newton–Hotelling `Xₖ₊₁ = Xₖ(2I − A·Xₖ)`;
//! * [`matrix_exp`] — scaling-and-squaring with a Taylor core;
//! * [`matvec`] / [`arnoldi_extremal_eigs`] — distributed matrix-vector
//!   products and an Arnoldi/Lanczos-style extremal-eigenvalue estimator
//!   (used by the sign/inverse methods to bound spectra for scaling).
//!
//! The Newton recurrences are the natural repeated-multiply consumers of
//! the 2.5D steady-state pipeline, so each has two entry points sharing
//! **one** recurrence implementation (the [`NewtonCtx`] abstraction, so
//! the math can never diverge): the flat per-call path above, and
//! [`matrix_sign_resident`] / [`matrix_inverse_resident`], which run
//! every multiply through a [`PipelineSession`] — constant operands (the
//! `A` of Newton–Hotelling, the identity, elementwise derivations) stay
//! layer-resident across iterations and never re-enter the replication
//! or skew paths; only each step's fresh product is re-admitted.

use crate::backend::gpu_sim::DeviceOom;
use crate::dist::{CommView, Grid2D, Payload};
use crate::matrix::matrix::Fill;
use crate::matrix::{DistMatrix, Mode};
use crate::multiply::session::Sides;
use crate::multiply::{multiply, MultiplyConfig, PipelineSession, ResidentOperand};

/// `C = A·B` through the configured pipeline (thin wrapper used below).
fn mm(grid: &Grid2D, a: &DistMatrix, b: &DistMatrix, cfg: &MultiplyConfig) -> Result<DistMatrix, DeviceOom> {
    Ok(multiply(grid, a, b, cfg)?.c)
}

/// The operations a Newton recurrence needs, abstracted over the matrix
/// handle so the flat (per-call `multiply()`) and steady-state
/// ([`PipelineSession`]) paths share one recurrence implementation.
trait NewtonCtx {
    type M: Clone;
    /// `A·B`. `out_sides` says which multiply sides the *product* will
    /// later appear on: `Both` for the next iterate, `B` for
    /// intermediates (X², A·X) that only feed elementwise derivations
    /// and right-hand multiplies — the resident context uses it to skip
    /// the A-side pre-skew those never need. The flat context ignores
    /// it.
    fn mm(&mut self, a: &Self::M, b: &Self::M, out_sides: Sides) -> Result<Self::M, DeviceOom>;
    fn identity_like(&mut self, like: &Self::M) -> Self::M;
    fn scale(&mut self, m: &mut Self::M, alpha: f32);
    fn add_scaled(&mut self, m: &mut Self::M, other: &Self::M, alpha: f32);
    /// Squared Frobenius norm of the global matrix (collective).
    fn frob_sq(&mut self, m: &Self::M) -> f32;
    /// `Aᵀ`, in the same logical distribution family as `A`.
    fn transpose(&mut self, m: &Self::M) -> Self::M;
}

/// Flat context: every multiply is an independent `multiply()` call over
/// the full grid (the pre-session behavior, bit for bit).
struct FlatCtx<'a> {
    grid: &'a Grid2D,
    cfg: &'a MultiplyConfig,
}

impl NewtonCtx for FlatCtx<'_> {
    type M = DistMatrix;

    fn mm(
        &mut self,
        a: &DistMatrix,
        b: &DistMatrix,
        _out_sides: Sides,
    ) -> Result<DistMatrix, DeviceOom> {
        mm(self.grid, a, b, self.cfg)
    }

    fn identity_like(&mut self, like: &DistMatrix) -> DistMatrix {
        identity_like(like)
    }

    fn scale(&mut self, m: &mut DistMatrix, alpha: f32) {
        m.scale(alpha);
    }

    fn add_scaled(&mut self, m: &mut DistMatrix, other: &DistMatrix, alpha: f32) {
        m.add_scaled(other, alpha);
    }

    fn frob_sq(&mut self, m: &DistMatrix) -> f32 {
        m.frobenius_sq(&self.grid.world)
    }

    fn transpose(&mut self, m: &DistMatrix) -> DistMatrix {
        crate::matrix::ops::transpose(m, &self.grid.world, (self.grid.rows, self.grid.cols))
    }
}

/// Steady-state context: multiplies run through the session on resident
/// handles. Each product comes back reduced onto layer 0, so it is
/// re-admitted (one |C| broadcast + pre-skew — the per-step cost the
/// 2.5D lineage paper pays in its iterative solves); everything else —
/// identities, scalings, axpys — derives in place on the replicas and
/// costs no residency traffic at all.
struct ResidentCtx<'a> {
    sess: &'a mut PipelineSession,
}

impl NewtonCtx for ResidentCtx<'_> {
    type M = ResidentOperand;

    fn mm(
        &mut self,
        a: &ResidentOperand,
        b: &ResidentOperand,
        out_sides: Sides,
    ) -> Result<ResidentOperand, DeviceOom> {
        let out = self.sess.multiply_resident(a, b)?;
        // the reduced C lives on layer 0 (zero elsewhere): admit
        // re-broadcasts and pre-skews it — only into the sides the
        // recurrence will actually multiply on
        Ok(self.sess.admit(out.c, out_sides))
    }

    fn identity_like(&mut self, like: &ResidentOperand) -> ResidentOperand {
        // built in place on each share's **native** pattern (NOT via
        // `identity_like`, which lays out the canonical cyclic share —
        // elementwise ops between the two layouts would silently mix
        // block positions); per layer the share covers the matrix once,
        // so the 1s land exactly once collectively, with no traffic
        ResidentOperand::from_shares(
            like.a_share().map(identity_on_pattern),
            like.b_share().map(identity_on_pattern),
        )
    }

    fn scale(&mut self, m: &mut ResidentOperand, alpha: f32) {
        m.scale(alpha);
    }

    fn add_scaled(&mut self, m: &mut ResidentOperand, other: &ResidentOperand, alpha: f32) {
        m.add_scaled(other, alpha);
    }

    fn frob_sq(&mut self, m: &ResidentOperand) -> f32 {
        // each layer's share covers the global matrix exactly once, so
        // a world-wide reduction counts it `layers` times — divide back
        // out. Reducing over the FULL world (not per layer) is load
        // bearing: per-layer reductions would group the f32 partial
        // sums differently on every layer (the native partitions
        // differ), and an err-vs-tol decision differing by one ulp
        // across layers would desynchronize the collective Newton loop.
        let g3 = self.sess.grid();
        m.share().frobenius_sq(&g3.world) / g3.layers as f32
    }

    fn transpose(&mut self, m: &ResidentOperand) -> ResidentOperand {
        // per-layer transpose of the covering share → the canonical
        // cyclic Aᵀ, bit-identical across layers (same deterministic
        // collective on replica data), then re-skewed resident
        let g3 = self.sess.grid();
        let t = crate::matrix::ops::transpose(m.share(), &g3.grid.world, (g3.rows, g3.cols));
        self.sess.adopt(&t, Sides::Both)
    }
}

/// Write 1s on the main diagonals of whatever diagonal blocks this
/// rank's (zeroed, real-mode) matrix holds — the shared core of both
/// identity constructors, so the flat and resident paths can never
/// diverge on ragged-diagonal semantics.
fn fill_identity_diagonal(m: &mut DistMatrix) {
    if m.mode != Mode::Real {
        return;
    }
    let blocks: Vec<(usize, usize, usize, usize)> = m
        .local
        .iter_nnz()
        .map(|(b, r, c)| (b, r, c, m.local.area_of(r, c)))
        .collect();
    for (b, r, c, area) in blocks {
        let (gi, gj) = (m.local.row_ids[r], m.local.col_ids[c]);
        if gi != gj {
            continue;
        }
        let cs = m.local.col_sizes[c];
        let rs = m.local.row_sizes[r];
        let blk = m.local.store.block_mut(b, area);
        for i in 0..rs.min(cs) {
            blk[i * cs + i] = 1.0;
        }
    }
}

/// The identity on `like`'s **local block pattern**: a zeroed clone with
/// 1s on the diagonals of whatever diagonal blocks this rank holds.
/// Unlike [`identity_like`] this preserves non-canonical layouts (the
/// 2.5D native shares), where the local blocks are not the cyclic set.
fn identity_on_pattern(like: &DistMatrix) -> DistMatrix {
    assert_eq!(like.rows.dim, like.cols.dim, "identity needs square");
    let mut m = like.clone();
    if m.mode == Mode::Real {
        m.local.store.data_mut().iter_mut().for_each(|x| *x = 0.0);
    }
    fill_identity_diagonal(&mut m);
    m
}

/// Distributed identity with the same layout/distribution as `like`.
pub fn identity_like(like: &DistMatrix) -> DistMatrix {
    assert_eq!(like.rows.dim, like.cols.dim, "identity needs square");
    let mut m = DistMatrix::dense(
        like.rows.clone(),
        like.cols.clone(),
        like.row_dist.clone(),
        like.col_dist.clone(),
        like.coords,
        like.mode,
        Fill::Zero,
    );
    fill_identity_diagonal(&mut m);
    m
}

/// The Newton–Schulz sign recurrence, shared by the flat and resident
/// entry points (same operation sequence → same numerics per path).
fn sign_core<C: NewtonCtx>(
    ctx: &mut C,
    a: &C::M,
    max_iter: usize,
    tol: f32,
) -> Result<(C::M, usize), DeviceOom> {
    let mut x = a.clone();
    // the identity derives in X²'s share space so the elementwise ops
    // line up handle-for-handle; its pattern is iteration-invariant, so
    // build it once on the first product
    let mut id_cache: Option<C::M> = None;
    for it in 0..max_iter {
        // X²: only an elementwise source and a right-hand operand
        let x2 = ctx.mm(&x, &x, Sides::B)?;
        if id_cache.is_none() {
            id_cache = Some(ctx.identity_like(&x2));
        }
        let id = id_cache.as_ref().expect("identity cached");
        // Y = 3I − X²; then X ← ½ X Y
        let mut y = id.clone();
        ctx.scale(&mut y, 3.0);
        ctx.add_scaled(&mut y, &x2, -1.0);
        let mut next = ctx.mm(&x, &y, Sides::Both)?;
        ctx.scale(&mut next, 0.5);
        // convergence: ‖X² − I‖_F (reuse x2)
        let mut resid = x2.clone();
        ctx.add_scaled(&mut resid, id, -1.0);
        let err = ctx.frob_sq(&resid).sqrt();
        x = next;
        if err < tol {
            return Ok((x, it + 1));
        }
    }
    Ok((x, max_iter))
}

/// The Newton–Hotelling inverse recurrence (see [`sign_core`]).
fn inverse_core<C: NewtonCtx>(
    ctx: &mut C,
    a: &C::M,
    max_iter: usize,
    tol: f32,
) -> Result<(C::M, usize), DeviceOom> {
    // X0 = A^T / ||A||_F^2 — convergent for any nonsingular A when the
    // condition number is moderate (our tests use diagonally-dominant A)
    let fro2 = ctx.frob_sq(a);
    let mut x = ctx.transpose(a);
    ctx.scale(&mut x, 1.0 / fro2);
    // identity in A·X's share space, built once (see sign_core)
    let mut id_cache: Option<C::M> = None;
    for it in 0..max_iter {
        // A·X: elementwise source + right-hand operand only
        let ax = ctx.mm(a, &x, Sides::B)?;
        if id_cache.is_none() {
            id_cache = Some(ctx.identity_like(&ax));
        }
        let id = id_cache.as_ref().expect("identity cached");
        let mut y = id.clone();
        ctx.scale(&mut y, 2.0);
        ctx.add_scaled(&mut y, &ax, -1.0);
        let next = ctx.mm(&x, &y, Sides::Both)?;
        // residual ‖A·X − I‖
        let mut resid = ax;
        ctx.add_scaled(&mut resid, id, -1.0);
        let err = ctx.frob_sq(&resid).sqrt();
        x = next;
        if err < tol {
            return Ok((x, it + 1));
        }
    }
    Ok((x, max_iter))
}

/// Matrix sign function via Newton–Schulz: `Xₖ₊₁ = ½ Xₖ (3I − Xₖ²)`.
///
/// Converges quadratically for matrices with `‖I − A²‖ < 1`; callers
/// pre-scale by the spectral bound (see [`arnoldi_extremal_eigs`]).
/// Returns (sign(A), iterations used).
pub fn matrix_sign(
    grid: &Grid2D,
    a: &DistMatrix,
    cfg: &MultiplyConfig,
    max_iter: usize,
    tol: f32,
) -> Result<(DistMatrix, usize), DeviceOom> {
    sign_core(&mut FlatCtx { grid, cfg }, a, max_iter, tol)
}

/// Newton–Hotelling inverse: `Xₖ₊₁ = Xₖ (2I − A Xₖ)`, seeded with
/// `X₀ = αAᵀ ≈ A⁻¹` (α = 1/‖A‖² estimate from `‖A‖_F`).
pub fn matrix_inverse(
    grid: &Grid2D,
    a: &DistMatrix,
    cfg: &MultiplyConfig,
    max_iter: usize,
    tol: f32,
) -> Result<(DistMatrix, usize), DeviceOom> {
    inverse_core(&mut FlatCtx { grid, cfg }, a, max_iter, tol)
}

/// [`matrix_sign`] through a steady-state [`PipelineSession`]: `a` is a
/// canonical layer-cyclic share over the session's layer grid (layers
/// > 0 may hold zeros — admission broadcasts layer 0's data); it is
/// admitted **once** and every `X·X` / `X·Y` of the iteration runs
/// skew- and replication-free on resident handles. Returns the
/// resident sign (its per-layer share covers the matrix exactly once)
/// plus the iteration count; the amortized setup is visible in
/// `session.stats().repl_bytes` vs the per-call counters.
pub fn matrix_sign_resident(
    session: &mut PipelineSession,
    a: &DistMatrix,
    max_iter: usize,
    tol: f32,
) -> Result<(ResidentOperand, usize), DeviceOom> {
    let ra = session.admit(a.clone(), Sides::Both);
    sign_core(&mut ResidentCtx { sess: session }, &ra, max_iter, tol)
}

/// [`matrix_inverse`] through a steady-state [`PipelineSession`] — the
/// clearest amortization case: the constant `A` of `A·Xₖ` is admitted
/// once and reused by every iteration (the flat path re-replicates it
/// per multiply under a 2.5D config).
pub fn matrix_inverse_resident(
    session: &mut PipelineSession,
    a: &DistMatrix,
    max_iter: usize,
    tol: f32,
) -> Result<(ResidentOperand, usize), DeviceOom> {
    let ra = session.admit(a.clone(), Sides::Both);
    inverse_core(&mut ResidentCtx { sess: session }, &ra, max_iter, tol)
}

/// Matrix exponential by scaling-and-squaring: `exp(A) = (exp(A/2ˢ))^(2ˢ)`
/// with an order-`taylor` Taylor core.
pub fn matrix_exp(
    grid: &Grid2D,
    a: &DistMatrix,
    cfg: &MultiplyConfig,
    taylor: usize,
) -> Result<DistMatrix, DeviceOom> {
    // pick s so ‖A/2^s‖_F ≲ 0.5
    let norm = a.frobenius_sq(&grid.world).sqrt();
    let s = norm.max(1e-30).log2().ceil().max(0.0) as u32 + 1;
    let mut small = a.clone();
    small.scale(1.0 / (1u64 << s) as f32);

    // Taylor: E = I + X (I/1! + X/2! (I + ...)) — Horner form
    let id = identity_like(a);
    let mut e = id.clone();
    for j in (1..=taylor).rev() {
        // e ← I + (X · e) / j
        let xe = mm(grid, &small, &e, cfg)?;
        e = id.clone();
        e.add_scaled(&xe, 1.0 / j as f32);
    }
    // square s times
    for _ in 0..s {
        e = mm(grid, &e, &e, cfg)?;
    }
    Ok(e)
}

/// Distributed matrix-vector product `y = A·x` with `x` replicated on
/// every rank (length = global cols). Collective.
pub fn matvec(a: &DistMatrix, x: &[f32], world: &CommView) -> Vec<f32> {
    assert_eq!(a.mode, Mode::Real);
    let (m, n) = a.global_dims();
    assert_eq!(x.len(), n);
    let mut local = vec![0.0f32; m];
    for (b, r, c) in a.local.iter_nnz() {
        let (gi, gj) = (a.local.row_ids[r], a.local.col_ids[c]);
        let (rs, cs) = (a.local.row_sizes[r], a.local.col_sizes[c]);
        let (r0, c0) = (a.rows.block_start(gi), a.cols.block_start(gj));
        let blk = a.local.store.block(b, rs * cs);
        for i in 0..rs {
            let mut acc = 0.0f32;
            for j in 0..cs {
                acc += blk[i * cs + j] * x[c0 + j];
            }
            local[r0 + i] += acc;
        }
    }
    world.allreduce_sum_f32(Payload::F32(local)).into_f32()
}

/// Arnoldi (symmetric: Lanczos-like) extremal-eigenvalue estimate via
/// power-type iteration with Rayleigh quotients over `iters` steps.
/// Returns (λ_max estimate, final Rayleigh residual).
pub fn arnoldi_extremal_eigs(
    a: &DistMatrix,
    world: &CommView,
    iters: usize,
    seed: u64,
) -> (f32, f32) {
    let (_, n) = a.global_dims();
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v: Vec<f32> = (0..n).map(|_| rng.next_f32_sym()).collect();
    normalize(&mut v);
    let mut lambda = 0.0f32;
    let mut resid = f32::INFINITY;
    for _ in 0..iters {
        let w = matvec(a, &v, world);
        lambda = dot(&v, &w); // Rayleigh quotient (v normalized)
        // residual ‖Av − λv‖
        resid = w
            .iter()
            .zip(v.iter())
            .map(|(wi, vi)| (wi - lambda * vi).powi(2))
            .sum::<f32>()
            .sqrt();
        v = w;
        normalize(&mut v);
    }
    (lambda, resid)
}

fn normalize(v: &mut [f32]) {
    let n = dot(v, v).sqrt().max(1e-30);
    for x in v.iter_mut() {
        *x /= n;
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{run_ranks, NetModel};
    use crate::matrix::{BlockLayout, Distribution};

    /// Well-conditioned symmetric test matrix: D + εR with dominant
    /// diagonal, distributed on a 2×2 grid.
    fn test_matrix(coords: (usize, usize), n: usize, block: usize, eps: f32) -> DistMatrix {
        let mut a = DistMatrix::dense(
            BlockLayout::new(n, block),
            BlockLayout::new(n, block),
            Distribution::cyclic(2),
            Distribution::cyclic(2),
            coords,
            Mode::Real,
            Fill::Random { seed: 300 },
        );
        // symmetrize-ish + diagonal dominance: A = εR + 2I-ish diag
        a.scale(eps);
        let blocks: Vec<(usize, usize, usize, usize)> = a
            .local
            .iter_nnz()
            .map(|(b, r, c)| (b, r, c, a.local.area_of(r, c)))
            .collect();
        for (b, r, c, area) in blocks {
            let (gi, gj) = (a.local.row_ids[r], a.local.col_ids[c]);
            if gi != gj {
                continue;
            }
            let cs = a.local.col_sizes[c];
            let rs = a.local.row_sizes[r];
            let blk = a.local.store.block_mut(b, area);
            for i in 0..rs.min(cs) {
                blk[i * cs + i] += 1.0;
            }
        }
        a
    }

    #[test]
    fn identity_like_is_identity() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.0);
            let id = identity_like(&a);
            id.trace(&grid.world)
        });
        assert!((out[0] - 24.0).abs() < 1e-4);
    }

    #[test]
    fn sign_of_spd_matrix_is_identity() {
        // A ≈ I + εR has positive spectrum → sign(A) = I
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.05);
            let cfg = MultiplyConfig::default();
            let (s, iters) = matrix_sign(&grid, &a, &cfg, 30, 1e-4).unwrap();
            let mut diff = s.clone();
            diff.add_scaled(&identity_like(&s), -1.0);
            (diff.frobenius_sq(&grid.world).sqrt(), iters)
        });
        let (err, iters) = out[0];
        assert!(err < 1e-2, "‖sign(A) − I‖ = {err} after {iters} iters");
        assert!(iters < 30, "should converge");
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.05);
            let cfg = MultiplyConfig::default();
            let (inv, iters) = matrix_inverse(&grid, &a, &cfg, 50, 1e-4).unwrap();
            let ax = multiply(&grid, &a, &inv, &cfg).unwrap().c;
            let mut diff = ax;
            diff.add_scaled(&identity_like(&a), -1.0);
            (diff.frobenius_sq(&grid.world).sqrt(), iters)
        });
        let (err, iters) = out[0];
        assert!(err < 1e-2, "‖A·A⁻¹ − I‖ = {err} after {iters} iters");
    }

    #[test]
    fn exp_of_zero_is_identity() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 16, 4, 0.0);
            let mut z = a.clone();
            z.scale(0.0);
            // zero out diagonal too: build from Fill::Zero directly
            let z = DistMatrix::dense(
                z.rows.clone(),
                z.cols.clone(),
                z.row_dist.clone(),
                z.col_dist.clone(),
                z.coords,
                Mode::Real,
                Fill::Zero,
            );
            let cfg = MultiplyConfig::default();
            let e = matrix_exp(&grid, &z, &cfg, 8).unwrap();
            let mut diff = e;
            diff.add_scaled(&identity_like(&a), -1.0);
            diff.frobenius_sq(&grid.world).sqrt()
        });
        assert!(out[0] < 1e-4, "exp(0) ≠ I: {}", out[0]);
    }

    #[test]
    fn exp_trace_matches_scalar_exp_for_diagonal() {
        // A = c·I → exp(A) = e^c·I, trace = n·e^c
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let base = test_matrix(grid.coords(), 16, 4, 0.0); // I
            let mut a = base.clone();
            a.scale(0.5); // A = 0.5 I
            let cfg = MultiplyConfig::default();
            let e = matrix_exp(&grid, &a, &cfg, 10).unwrap();
            e.trace(&grid.world)
        });
        let want = 16.0 * 0.5f32.exp();
        assert!((out[0] - want).abs() / want < 1e-3, "{} vs {want}", out[0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 20, 5, 0.3);
            let x: Vec<f32> = (0..20).map(|i| (i as f32 * 0.37).sin()).collect();
            let y = matvec(&a, &x, &grid.world);
            let mut dense = vec![0.0f32; 20 * 20];
            a.add_into_dense(&mut dense);
            (y, dense)
        });
        // reconstruct global dense from all ranks
        let mut full = vec![0.0f32; 400];
        for (_, d) in &out {
            for (f, x) in full.iter_mut().zip(d.iter()) {
                *f += x;
            }
        }
        let x: Vec<f32> = (0..20).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut want = vec![0.0f32; 20];
        for i in 0..20 {
            for j in 0..20 {
                want[i] += full[i * 20 + j] * x[j];
            }
        }
        // ranks each computed a PARTIAL dense view but matvec allreduced:
        // y should equal full matvec on every rank
        for (yi, wi) in out[0].0.iter().zip(want.iter()) {
            assert!((yi - wi).abs() < 1e-3, "{yi} vs {wi}");
        }
    }

    #[test]
    fn sign_resident_matches_flat_semantics() {
        // the steady-state path must converge to the same sign(A) = I
        // for an SPD matrix, with the residency setup charged once to
        // the session and never to a multiply
        use crate::dist::Grid3D;
        let out = run_ranks(8, NetModel::ideal(), |world| {
            let g3 = Grid3D::new(world, 2, 2, 2);
            let a = test_matrix(g3.grid.coords(), 24, 6, 0.05);
            let mut sess = PipelineSession::new(g3, MultiplyConfig::default());
            let (s, iters) = matrix_sign_resident(&mut sess, &a, 30, 1e-4).unwrap();
            // subtract the identity on the share's NATIVE pattern —
            // identity_like's canonical pattern would misalign blocks
            let mut share = s.share().clone();
            let idm = identity_on_pattern(&share);
            share.add_scaled(&idm, -1.0);
            let err = share.frobenius_sq(&sess.grid().grid.world).sqrt();
            (err, iters, sess.stats().repl_bytes)
        });
        let (err, iters, repl_bytes) = out[0];
        assert!(err < 1e-2, "‖sign(A) − I‖ = {err} after {iters} iters");
        assert!(iters < 30, "should converge");
        assert!(repl_bytes > 0, "residency setup must be booked");
    }

    #[test]
    fn inverse_resident_times_a_is_identity() {
        use crate::dist::Grid3D;
        let out = run_ranks(8, NetModel::ideal(), |world| {
            let g3 = Grid3D::new(world, 2, 2, 2);
            let a = test_matrix(g3.grid.coords(), 24, 6, 0.05);
            let mut sess = PipelineSession::new(g3, MultiplyConfig::default());
            let (inv, iters) = matrix_inverse_resident(&mut sess, &a, 50, 1e-4).unwrap();
            // A·A⁻¹ on resident handles; reduce the residual per layer
            let ra = sess.admit(a, Sides::A);
            let ax = sess.multiply_resident(&ra, &inv).unwrap();
            // C lands on layer 0 in canonical layout; measure there
            let layer = sess.grid().layer;
            let mut dense = vec![0.0f32; 24 * 24];
            ax.c.add_into_dense(&mut dense);
            (layer, dense, iters)
        });
        // sum layer-0 shares → A·A⁻¹, compare against I
        let mut got = vec![0.0f32; 24 * 24];
        for (layer, dense, _) in &out {
            if *layer == 0 {
                for (g, x) in got.iter_mut().zip(dense.iter()) {
                    *g += x;
                }
            }
        }
        let mut err = 0.0f64;
        for i in 0..24 {
            for j in 0..24 {
                let want = if i == j { 1.0 } else { 0.0 };
                err += (got[i * 24 + j] as f64 - want).powi(2);
            }
        }
        let (_, _, iters) = &out[0];
        assert!(
            err.sqrt() < 1e-2,
            "‖A·A⁻¹ − I‖ = {} after {iters} iters",
            err.sqrt()
        );
    }

    #[test]
    fn arnoldi_finds_dominant_eigenvalue() {
        // A = I + 0.05 R: spectrum clustered near 1; λ_max slightly above
        let out = run_ranks(4, NetModel::ideal(), |world| {
            let grid = Grid2D::new(world, 2, 2);
            let a = test_matrix(grid.coords(), 24, 6, 0.05);
            arnoldi_extremal_eigs(&a, &grid.world, 40, 5)
        });
        let (lambda, resid) = out[0];
        assert!((0.8..1.6).contains(&lambda), "λ={lambda}");
        assert!(resid < 0.2, "residual {resid}");
    }
}
