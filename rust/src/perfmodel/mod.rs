//! Compute-device timing model (the P100 + Xeon substitution).
//!
//! Model mode advances each rank's virtual clock through these curves; the
//! parameters are calibrated to published Piz Daint-era numbers:
//!
//! * NVIDIA P100: 4.7 TFLOP/s FP64 peak; cuBLAS `dgemm` efficiency rises
//!   with problem size (half-efficiency around a ~500³ problem); kernel
//!   launch + cuBLAS dispatch ≈ 8 µs.
//! * LIBCUSMM-style batched small-matmul: per-stack launch ≈ 15 µs, with a
//!   block-size efficiency curve; its speedup over a batched-cuBLAS-style
//!   baseline is 2–4× below size 32 and fades to ~1 by 80 (§II of the
//!   paper; our E7 bench regenerates this curve).
//! * Xeon E5-2690 v3: 41.6 GFLOP/s FP64 per core (2.6 GHz × 16 FLOP/cyc);
//!   LIBXSMM-style small-GEMM efficiency curve per thread.
//! * PCIe gen3 ×16: ≈ 11.3 GB/s pinned, 10 µs per-transfer latency.
//! * Host memcpy (densify/undensify copies): ≈ 8 GB/s per thread.
//! * GPU sharing: `R` ranks per node share one P100 through MPS; under
//!   full load each rank sees `peak / R` (fair-share approximation).
//!
//! The figures depend on the *ratios* between these curves and the network
//! model, not on absolute accuracy — see DESIGN.md §3.

/// All tunable device-model parameters.
#[derive(Clone, Debug)]
pub struct PerfModel {
    /// GPU FP64 peak, FLOP/s (per full device).
    pub gpu_peak: f64,
    /// √(m·n) at which cuBLAS DGEMM reaches half peak (output-tile
    /// quantization / occupancy term).
    pub gemm_mn_half: f64,
    /// k at which cuBLAS DGEMM reaches half peak (k-loop amortization
    /// term — the paper's "PDGEMM slow for small blocks" effect).
    pub gemm_k_half: f64,
    /// Per-call GPU launch/dispatch overhead, seconds.
    pub gpu_call_overhead: f64,
    /// Per-stack overhead for batched SMM kernels, seconds.
    pub smm_stack_overhead: f64,
    /// Block size at which the SMM kernel reaches half of GPU peak.
    pub smm_half_size: f64,
    /// CPU FP64 peak per core, FLOP/s.
    pub cpu_core_peak: f64,
    /// Block size at which CPU small-GEMM reaches half of core peak.
    pub cpu_half_size: f64,
    /// Host↔device bandwidth (pinned), bytes/s.
    pub pcie_bw: f64,
    /// Host↔device per-transfer latency, seconds.
    pub pcie_lat: f64,
    /// Host memcpy bandwidth for densify copies, bytes/s per thread.
    pub memcpy_bw: f64,
    /// Host-side per-stack handling cost (generation + scheduling), s.
    pub stack_host_overhead: f64,
    /// Host-side per-entry cost of building a stack, seconds.
    pub entry_gen_cost: f64,
    /// Device memory capacity, bytes.
    pub gpu_mem_bytes: u64,
    /// Memory-pool slack factor (pools retain high-water buffers).
    pub pool_slack: f64,
}

impl Default for PerfModel {
    fn default() -> Self {
        PerfModel {
            gpu_peak: 4.7e12,
            gemm_mn_half: 250.0,
            gemm_k_half: 4.0,
            gpu_call_overhead: 8e-6,
            smm_stack_overhead: 15e-6,
            smm_half_size: 26.0,
            cpu_core_peak: 41.6e9,
            cpu_half_size: 18.0,
            pcie_bw: 11.3e9,
            pcie_lat: 10e-6,
            memcpy_bw: 2.5e9,
            stack_host_overhead: 12e-6,
            entry_gen_cost: 25e-9,
            gpu_mem_bytes: 16 << 30,
            pool_slack: 1.75,
        }
    }
}

impl PerfModel {
    /// cuBLAS-like DGEMM efficiency for an (m × k)·(k × n) product:
    /// separable output-size (√(m·n)) and k-depth saturation terms.
    /// The k term is what punishes PDGEMM's block-width panels (§IV-C);
    /// the mn term is what shrinks densified-panel efficiency as the
    /// grid grows (part of Fig. 3's declining ratio).
    pub fn gemm_efficiency(&self, m: usize, n: usize, k: usize) -> f64 {
        let s_mn = ((m as f64) * (n as f64)).sqrt();
        let kf = k as f64;
        (s_mn / (s_mn + self.gemm_mn_half)) * (kf / (kf + self.gemm_k_half))
    }

    /// Seconds for one large GEMM on a GPU share of `1/share` of the card.
    pub fn gpu_gemm_seconds(&self, m: usize, n: usize, k: usize, share: usize) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let rate = self.gpu_peak / share as f64 * self.gemm_efficiency(m, n, k);
        self.gpu_call_overhead + flops / rate
    }

    /// LIBCUSMM-analog efficiency for block size `b` (uniform m=n=k=b).
    pub fn smm_efficiency(&self, b: usize) -> f64 {
        let b = b as f64;
        b / (b + self.smm_half_size)
    }

    /// Batched-cuBLAS-analog efficiency: the SMM curve divided by the
    /// paper's observed speedup ratio (2–4× below 32, ≈1 by 80).
    pub fn cublas_batched_efficiency(&self, b: usize) -> f64 {
        self.smm_efficiency(b) / self.smm_speedup_ratio(b)
    }

    /// The §II speedup of LIBCUSMM over batched cuBLAS.
    pub fn smm_speedup_ratio(&self, b: usize) -> f64 {
        1.0 + 3.0 * (-(b as f64) / 20.0).exp()
    }

    /// Seconds to execute one stack of `entries` (m,n,k) multiplications
    /// on a GPU share of `1/share`.
    pub fn gpu_stack_seconds(
        &self,
        entries: usize,
        m: usize,
        n: usize,
        k: usize,
        share: usize,
    ) -> f64 {
        let b = ((m * n * k) as f64).cbrt();
        let eff = b / (b + self.smm_half_size);
        let flops = 2.0 * entries as f64 * (m * n * k) as f64;
        self.smm_stack_overhead + flops / (self.gpu_peak / share as f64 * eff)
    }

    /// Seconds to execute one stack on one CPU thread (LIBXSMM analog).
    pub fn cpu_stack_seconds(&self, entries: usize, m: usize, n: usize, k: usize) -> f64 {
        let b = ((m * n * k) as f64).cbrt();
        let eff = b / (b + self.cpu_half_size);
        let flops = 2.0 * entries as f64 * (m * n * k) as f64;
        flops / (self.cpu_core_peak * eff)
    }

    /// Seconds for one large GEMM on `threads` CPU cores.
    pub fn cpu_gemm_seconds(&self, m: usize, n: usize, k: usize, threads: usize) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let eff = self.gemm_efficiency(m, n, k).max(0.5); // large-GEMM BLAS
        flops / (self.cpu_core_peak * threads as f64 * eff)
    }

    /// Host↔device transfer time for `bytes`.
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        self.pcie_lat + bytes as f64 / self.pcie_bw
    }

    /// Densify/undensify copy time for `bytes` on one thread.
    pub fn memcpy_seconds(&self, bytes: u64) -> f64 {
        bytes as f64 / self.memcpy_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_efficiency_monotone_saturating() {
        let p = PerfModel::default();
        let e1 = p.gemm_efficiency(64, 64, 64);
        let e2 = p.gemm_efficiency(1000, 1000, 1000);
        let e3 = p.gemm_efficiency(16000, 16000, 16000);
        assert!(e1 < e2 && e2 < e3);
        assert!(e3 < 1.0 && e3 > 0.9);
    }

    #[test]
    fn smm_beats_batched_cublas_small() {
        let p = PerfModel::default();
        // paper §II: 2–4x below 32, fading by 80
        for b in [4usize, 8, 16, 22] {
            let r = p.smm_speedup_ratio(b);
            assert!((1.9..=4.1).contains(&r), "b={b} ratio={r}");
        }
        let r80 = p.smm_speedup_ratio(80);
        assert!(r80 < 1.1, "ratio at 80 = {r80}");
    }

    #[test]
    fn gpu_share_scales_time() {
        let p = PerfModel::default();
        let t1 = p.gpu_gemm_seconds(2000, 2000, 2000, 1);
        let t12 = p.gpu_gemm_seconds(2000, 2000, 2000, 12);
        assert!(t12 > 10.0 * t1 && t12 < 12.5 * t1);
    }

    #[test]
    fn big_gemm_beats_small_stacks_per_flop() {
        // the densification premise: the same flops run faster as one
        // large GEMM (a paper-scale densified panel) than as b22 stacks
        let p = PerfModel::default();
        let (m, n, k) = (2640, 7920, 7920); // P=64, t=3 densified panel
        let flops = 2.0 * (m * n * k) as f64;
        let t_gemm = p.gpu_gemm_seconds(m, n, k, 1);
        let entries = (m * n * k) / (22 * 22 * 22);
        let t_stacks = (entries / 30_000 + 1) as f64
            * p.gpu_stack_seconds(30_000, 22, 22, 22, 1);
        assert!(
            t_gemm < t_stacks,
            "gemm {t_gemm} should beat stacks {t_stacks} for {flops} flops"
        );
        // and the per-flop advantage is roughly the efficiency ratio
        let ratio = t_stacks / t_gemm;
        assert!((1.3..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn transfer_and_memcpy_positive() {
        let p = PerfModel::default();
        assert!(p.transfer_seconds(1 << 20) > p.pcie_lat);
        assert!(p.memcpy_seconds(1 << 20) > 0.0);
    }

    #[test]
    fn cpu_slower_than_full_gpu_for_big_blocks() {
        let p = PerfModel::default();
        let tc = p.cpu_stack_seconds(1000, 64, 64, 64);
        let tg = p.gpu_stack_seconds(1000, 64, 64, 64, 1);
        assert!(tc > tg);
    }
}
