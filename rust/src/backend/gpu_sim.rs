//! The simulated GPU device (P100 substitution).
//!
//! Reproduces the *structure* of DBCSR's GPU path — memory-pool buffers,
//! page-locked staging, two CUDA-stream analogs with double buffering, one
//! kernel engine — with numerics executed for real (PJRT-run Pallas
//! artifacts, CPU microkernel fallback) and time kept on a virtual
//! pipeline driven by [`PerfModel`]:
//!
//! * per stack/GEMM: H2D staging on the issuing stream's transfer engine,
//!   kernel on the device-wide kernel engine (serialized, shared across
//!   the node's ranks via the MPS fair-share factor), D2H back on the
//!   stream — so the transfer of stack *i+1* overlaps the kernel of *i*
//!   exactly as the paper's double-buffering scheme intends;
//! * device memory is pool-accounted (high-water × slack) against the
//!   16 GB capacity; exceeding it is the OOM the paper reports for the
//!   1×12 @ 16-node configuration (Fig. 2).

use std::rc::Rc;

use crate::backend::smm_cpu;
use crate::backend::stack::{Stack, StackEntries};
use crate::perfmodel::PerfModel;
use crate::runtime::{Runtime, VariantKind};

/// Device out-of-memory (the Fig. 2 annotation).
#[derive(Debug)]
pub struct DeviceOom {
    pub need: u64,
    pub cap: u64,
    pub peak: u64,
}

impl std::fmt::Display for DeviceOom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "simulated GPU out of memory: need {} B, capacity {} B (pool high-water {} B)",
            self.need, self.cap, self.peak
        )
    }
}

impl std::error::Error for DeviceOom {}

#[derive(Clone, Copy, Debug, Default)]
struct Stream {
    transfer_free: f64,
}

/// One rank's share of the (simulated) node GPU.
pub struct GpuSim {
    pub perf: PerfModel,
    /// Ranks sharing this card through MPS (= ranks per node).
    pub share: usize,
    /// PJRT runtime for real numerics (None → CPU microkernel numerics).
    runtime: Option<Rc<Runtime>>,
    streams: [Stream; 2],
    next_stream: usize,
    kernel_free: f64,
    /// Pool accounting, bytes.
    mem_used: u64,
    pub mem_peak: u64,
    // counters
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub kernels: u64,
    // reusable staging buffers (pinned-host analogs)
    scratch_a: Vec<f32>,
    scratch_b: Vec<f32>,
    scratch_c: Vec<f32>,
}

impl GpuSim {
    /// A pristine device sim with this one's configuration (perf model,
    /// MPS share, runtime) but zeroed clocks, pools and counters.
    pub fn fresh(&self) -> GpuSim {
        GpuSim::new(self.perf.clone(), self.share, self.runtime.clone())
    }

    pub fn new(perf: PerfModel, share: usize, runtime: Option<Rc<Runtime>>) -> GpuSim {
        GpuSim {
            perf,
            share: share.max(1),
            runtime,
            streams: [Stream::default(); 2],
            next_stream: 0,
            kernel_free: 0.0,
            mem_used: 0,
            mem_peak: 0,
            h2d_bytes: 0,
            d2h_bytes: 0,
            kernels: 0,
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
            scratch_c: Vec::new(),
        }
    }

    /// Reset pipeline clocks and counters (between bench repetitions);
    /// keeps pool high-water (pools persist across multiplications).
    pub fn reset_pipeline(&mut self) {
        self.streams = [Stream::default(); 2];
        self.kernel_free = 0.0;
        self.h2d_bytes = 0;
        self.d2h_bytes = 0;
        self.kernels = 0;
    }

    // ----- memory pool ----------------------------------------------------

    /// Reserve `bytes` of device memory from the pool.
    pub fn reserve(&mut self, bytes: u64) -> Result<(), DeviceOom> {
        self.mem_used += bytes;
        let effective = (self.mem_used as f64 * self.perf.pool_slack) as u64;
        self.mem_peak = self.mem_peak.max(effective);
        if self.mem_peak > self.perf.gpu_mem_bytes {
            return Err(DeviceOom {
                need: effective,
                cap: self.perf.gpu_mem_bytes,
                peak: self.mem_peak,
            });
        }
        Ok(())
    }

    /// Return `bytes` to the pool (buffers are reused, high-water stays).
    pub fn release(&mut self, bytes: u64) {
        self.mem_used = self.mem_used.saturating_sub(bytes);
    }

    // ----- virtual pipeline -----------------------------------------------

    /// Schedule one (h2d, kernel, d2h) op chain starting no earlier than
    /// `host_now`; returns the virtual completion time of the d2h.
    fn pipeline(&mut self, host_now: f64, h2d: u64, kernel_s: f64, d2h: u64) -> f64 {
        let s = self.next_stream;
        self.next_stream = (self.next_stream + 1) % self.streams.len();
        let t_h2d_start = host_now.max(self.streams[s].transfer_free);
        let t_h2d_done = t_h2d_start
            + if h2d > 0 {
                self.perf.transfer_seconds(h2d)
            } else {
                0.0
            };
        let t_kernel_start = t_h2d_done.max(self.kernel_free);
        let t_kernel_done = t_kernel_start + kernel_s;
        self.kernel_free = t_kernel_done;
        let t_d2h_done = t_kernel_done.max(t_h2d_done)
            + if d2h > 0 {
                self.perf.transfer_seconds(d2h)
            } else {
                0.0
            };
        self.streams[s].transfer_free = t_d2h_done;
        self.h2d_bytes += h2d;
        self.d2h_bytes += d2h;
        self.kernels += 1;
        t_d2h_done
    }

    /// Virtual time when everything issued so far has completed.
    pub fn sync(&self) -> f64 {
        self.streams
            .iter()
            .map(|s| s.transfer_free)
            .fold(self.kernel_free, f64::max)
    }

    /// Projected completion if a stack were issued now (scheduler uses
    /// this to decide GPU vs CPU, the paper's "GPU fully loaded" rule).
    pub fn projected_stack_finish(&self, host_now: f64, stack: &Stack) -> f64 {
        let s = &self.streams[self.next_stream];
        let t0 = host_now.max(s.transfer_free);
        let t1 = t0 + self.perf.transfer_seconds(stack.h2d_bytes());
        let t2 = t1.max(self.kernel_free)
            + self
                .perf
                .gpu_stack_seconds(stack.entries.len(), stack.m, stack.n, stack.k, self.share);
        t2 + self.perf.transfer_seconds(stack.d2h_bytes())
    }

    // ----- stack execution (blocked path) -----------------------------------

    /// Execute one stack on the device: numerics now (sequential testbed),
    /// virtual completion per the pipeline. `scale` multiplies the modeled
    /// wire/compute volume (model mode uses f64 bytes = 2× f32).
    pub fn run_stack(
        &mut self,
        host_now: f64,
        stack: &Stack,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        byte_scale: f64,
    ) -> f64 {
        let kernel_s = self
            .perf
            .gpu_stack_seconds(stack.entries.len(), stack.m, stack.n, stack.k, self.share);
        let done = self.pipeline(
            host_now,
            (stack.h2d_bytes() as f64 * byte_scale) as u64,
            kernel_s,
            (stack.d2h_bytes() as f64 * byte_scale) as u64,
        );
        if let StackEntries::Real(entries) = &stack.entries {
            self.exec_stack_numerics(stack.m, stack.n, stack.k, entries, a, b, c);
        }
        done
    }

    fn exec_stack_numerics(
        &mut self,
        m: usize,
        n: usize,
        k: usize,
        entries: &[crate::backend::stack::StackEntry],
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
    ) {
        // find an smm artifact matching (m,n,k)
        let variant = self.runtime.as_ref().and_then(|rt| {
            rt.manifest
                .variants
                .iter()
                .find(|v| matches!(v.kind, VariantKind::Smm { m: vm, n: vn, k: vk, .. } if (vm, vn, vk) == (m, n, k)))
                .map(|v| (v.name.clone(), v.kind))
        });
        match (self.runtime.clone(), variant) {
            (Some(rt), Some((name, VariantKind::Smm { s, .. }))) => {
                // chunk entries into the artifact's stack size, tail padded
                // with zero blocks (proven inert in python/tests). The C
                // inputs are zeros and the products are *accumulated* on
                // write-back: several entries of one stack may target the
                // same C block (different k), and per-entry C slots would
                // otherwise lose all but the last contribution.
                let (ak, bk, ck) = (m * k, k * n, m * n);
                self.scratch_c.clear();
                self.scratch_c.resize(s * ck, 0.0);
                // staging buffers are reused across chunks; only the tail
                // of a partial final chunk needs explicit zeroing (full
                // slots are overwritten below) — saves one full memset per
                // chunk on the hot path
                self.scratch_a.resize(s * ak, 0.0);
                self.scratch_b.resize(s * bk, 0.0);
                for chunk in entries.chunks(s) {
                    if chunk.len() < s {
                        self.scratch_a[chunk.len() * ak..].fill(0.0);
                        self.scratch_b[chunk.len() * bk..].fill(0.0);
                    }
                    for (i, e) in chunk.iter().enumerate() {
                        self.scratch_a[i * ak..(i + 1) * ak]
                            .copy_from_slice(&a[e.a_off..e.a_off + ak]);
                        self.scratch_b[i * bk..(i + 1) * bk]
                            .copy_from_slice(&b[e.b_off..e.b_off + bk]);
                    }
                    let out = rt
                        .execute(&name, &[&self.scratch_a, &self.scratch_b, &self.scratch_c])
                        .expect("smm artifact execution");
                    for (i, e) in chunk.iter().enumerate() {
                        for (cv, ov) in c[e.c_off..e.c_off + ck]
                            .iter_mut()
                            .zip(&out[i * ck..(i + 1) * ck])
                        {
                            *cv += ov;
                        }
                    }
                }
            }
            _ => {
                // LIBXSMM-analog fallback (no artifact for this shape)
                for e in entries {
                    smm_cpu::smm(
                        m,
                        n,
                        k,
                        &a[e.a_off..e.a_off + m * k],
                        &b[e.b_off..e.b_off + k * n],
                        &mut c[e.c_off..e.c_off + m * n],
                    );
                }
            }
        }
    }

    // ----- large GEMM (densified path) --------------------------------------

    /// Execute `C += A·B` (row-major panels) on the device. Real panels
    /// are tiled to the AOT gemm artifacts with zero padding; timing is
    /// one pipelined op (cuBLAS issues one kernel for the whole GEMM).
    /// `real` may be None in model mode. Transfer bytes are explicit so
    /// callers can keep pool-resident buffers (e.g. densified C stays on
    /// device across Cannon ticks) out of the per-call staging cost.
    #[allow(clippy::too_many_arguments)]
    pub fn run_gemm(
        &mut self,
        host_now: f64,
        m: usize,
        n: usize,
        k: usize,
        real: Option<(&[f32], &[f32], &mut [f32])>,
        h2d_bytes: u64,
        d2h_bytes: u64,
    ) -> f64 {
        let kernel_s = self.perf.gpu_gemm_seconds(m, n, k, self.share);
        let done = self.pipeline(host_now, h2d_bytes, kernel_s, d2h_bytes);
        if let Some((a, b, c)) = real {
            self.exec_gemm_numerics(m, n, k, a, b, c);
        }
        done
    }

    /// Schedule a bare transfer (no kernel) — e.g. fetching densified C
    /// at the end of the multiplication. Returns completion time.
    pub fn run_transfer(&mut self, host_now: f64, h2d_bytes: u64, d2h_bytes: u64) -> f64 {
        let s = self.next_stream;
        self.next_stream = (self.next_stream + 1) % self.streams.len();
        let t0 = host_now.max(self.streams[s].transfer_free);
        let mut done = t0;
        if h2d_bytes > 0 {
            done += self.perf.transfer_seconds(h2d_bytes);
        }
        if d2h_bytes > 0 {
            done += self.perf.transfer_seconds(d2h_bytes);
        }
        self.streams[s].transfer_free = done;
        self.h2d_bytes += h2d_bytes;
        self.d2h_bytes += d2h_bytes;
        done
    }

    fn exec_gemm_numerics(&mut self, m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        let tile = self
            .runtime
            .as_ref()
            .and_then(|rt| rt.pick_gemm_tile(m, n, k));
        match (self.runtime.clone(), tile) {
            (Some(rt), Some(t)) => {
                let name = format!("gemm_{t}");
                let (tm, tn, tk) = (m.div_ceil(t), n.div_ceil(t), k.div_ceil(t));
                for it in 0..tm {
                    for jt in 0..tn {
                        // gather C tile
                        gather_tile(c, m, n, it * t, jt * t, t, &mut self.scratch_c);
                        for kt in 0..tk {
                            gather_tile(a, m, k, it * t, kt * t, t, &mut self.scratch_a);
                            gather_tile(b, k, n, kt * t, jt * t, t, &mut self.scratch_b);
                            let out = rt
                                .execute(&name, &[&self.scratch_a, &self.scratch_b, &self.scratch_c])
                                .expect("gemm artifact execution");
                            self.scratch_c.copy_from_slice(&out);
                        }
                        scatter_tile(&self.scratch_c, c, m, n, it * t, jt * t, t);
                    }
                }
            }
            _ => smm_cpu::gemm_blocked(m, n, k, a, b, c),
        }
    }
}

/// Copy the (t × t) tile at (r0, c0) of an (rows × cols) matrix into
/// `out` (zero-padded outside the matrix).
fn gather_tile(src: &[f32], rows: usize, cols: usize, r0: usize, c0: usize, t: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(t * t, 0.0);
    let rmax = rows.saturating_sub(r0).min(t);
    let cmax = cols.saturating_sub(c0).min(t);
    for i in 0..rmax {
        let src_off = (r0 + i) * cols + c0;
        out[i * t..i * t + cmax].copy_from_slice(&src[src_off..src_off + cmax]);
    }
}

/// Write the valid region of a (t × t) tile back.
fn scatter_tile(tile: &[f32], dst: &mut [f32], rows: usize, cols: usize, r0: usize, c0: usize, t: usize) {
    let rmax = rows.saturating_sub(r0).min(t);
    let cmax = cols.saturating_sub(c0).min(t);
    for i in 0..rmax {
        let dst_off = (r0 + i) * cols + c0;
        dst[dst_off..dst_off + cmax].copy_from_slice(&tile[i * t..i * t + cmax]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::stack::{StackEntry, STACK_CAP};
    use crate::util::prop::assert_allclose;
    use crate::util::rng::Rng;

    fn perf() -> PerfModel {
        PerfModel::default()
    }

    #[test]
    fn pipeline_double_buffers() {
        let mut g = GpuSim::new(perf(), 1, None);
        // two ops: the second's h2d overlaps the first's kernel
        let t1 = g.pipeline(0.0, 1 << 20, 1e-3, 1 << 20);
        let t2 = g.pipeline(0.0, 1 << 20, 1e-3, 1 << 20);
        assert!(t2 > t1);
        let serial = 2.0 * (g.perf.transfer_seconds(1 << 20) * 2.0 + 1e-3);
        assert!(t2 < serial, "overlap should beat serial: {t2} vs {serial}");
    }

    #[test]
    fn kernel_engine_serializes() {
        let mut g = GpuSim::new(perf(), 1, None);
        let t1 = g.pipeline(0.0, 0, 1.0, 0);
        let t2 = g.pipeline(0.0, 0, 1.0, 0);
        assert!((t2 - t1 - 1.0).abs() < 1e-9, "kernels must serialize");
    }

    #[test]
    fn oom_detection() {
        let mut p = perf();
        p.gpu_mem_bytes = 1 << 20;
        let mut g = GpuSim::new(p, 1, None);
        assert!(g.reserve(512 << 10).is_ok());
        let err = g.reserve(512 << 10).unwrap_err();
        assert!(err.peak > err.cap);
    }

    #[test]
    fn release_and_high_water() {
        let mut g = GpuSim::new(perf(), 1, None);
        g.reserve(1000).unwrap();
        g.release(1000);
        g.reserve(500).unwrap();
        assert!(g.mem_peak >= 1000);
        assert_eq!(g.mem_used, 500);
    }

    #[test]
    fn stack_numerics_cpu_fallback() {
        let mut g = GpuSim::new(perf(), 1, None);
        let (m, n, k) = (5, 4, 3);
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..2 * m * k).map(|_| rng.next_f32_sym()).collect();
        let b: Vec<f32> = (0..2 * k * n).map(|_| rng.next_f32_sym()).collect();
        let mut c = vec![0.0f32; 2 * m * n];
        let stack = Stack {
            m,
            n,
            k,
            thread: 0,
            entries: StackEntries::Real(vec![
                StackEntry { a_off: 0, b_off: 0, c_off: 0 },
                StackEntry {
                    a_off: m * k,
                    b_off: k * n,
                    c_off: m * n,
                },
            ]),
        };
        let done = g.run_stack(0.0, &stack, &a, &b, &mut c, 1.0);
        assert!(done > 0.0);
        let mut want = vec![0.0f32; 2 * m * n];
        smm_cpu::gemm_naive(m, n, k, &a[..m * k], &b[..k * n], &mut want[..m * n]);
        smm_cpu::gemm_naive(m, n, k, &a[m * k..], &b[k * n..], &mut want[m * n..]);
        assert_allclose(&c, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn gemm_numerics_cpu_fallback() {
        let mut g = GpuSim::new(perf(), 1, None);
        let (m, n, k) = (33, 17, 21);
        let mut rng = Rng::new(4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32_sym()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
        let mut c = vec![1.0f32; m * n];
        let mut want = c.clone();
        let _ = g.run_gemm(0.0, m, n, k, Some((&a, &b, &mut c)), 4 * (m * k + k * n) as u64, 0);
        smm_cpu::gemm_naive(m, n, k, &a, &b, &mut want);
        assert_allclose(&c, &want, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn model_mode_stack_counts_time_only() {
        let mut g = GpuSim::new(perf(), 4, None);
        let stack = Stack {
            m: 22,
            n: 22,
            k: 22,
            thread: 0,
            entries: StackEntries::Model { count: STACK_CAP },
        };
        let mut c: Vec<f32> = vec![];
        let done = g.run_stack(0.0, &stack, &[], &[], &mut c, 2.0);
        assert!(done > 0.0);
        assert_eq!(g.kernels, 1);
        // byte_scale=2 doubles the modeled transfer volume
        assert_eq!(g.h2d_bytes, 2 * stack.h2d_bytes());
    }

    #[test]
    fn share_slows_kernels() {
        let stack = Stack {
            m: 64,
            n: 64,
            k: 64,
            thread: 0,
            entries: StackEntries::Model { count: 1000 },
        };
        let mut g1 = GpuSim::new(perf(), 1, None);
        let mut g12 = GpuSim::new(perf(), 12, None);
        let mut c: Vec<f32> = vec![];
        let t1 = g1.run_stack(0.0, &stack, &[], &[], &mut c, 1.0);
        let t12 = g12.run_stack(0.0, &stack, &[], &[], &mut c, 1.0);
        assert!(t12 > t1);
    }

    #[test]
    fn tile_gather_scatter_roundtrip() {
        let rows = 5;
        let cols = 7;
        let src: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
        let mut dst = vec![0.0f32; rows * cols];
        let mut tile = Vec::new();
        for r0 in (0..rows).step_by(4) {
            for c0 in (0..cols).step_by(4) {
                gather_tile(&src, rows, cols, r0, c0, 4, &mut tile);
                scatter_tile(&tile, &mut dst, rows, cols, r0, c0, 4);
            }
        }
        assert_eq!(src, dst);
    }
}
