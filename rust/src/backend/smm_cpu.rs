//! CPU small-matrix-multiply microkernels — the LIBXSMM analog.
//!
//! LIBXSMM JIT-generates SIMD microkernels per (m, n, k); this module's
//! equivalent is a set of rust microkernels specialized at compile time
//! for the hot square sizes (unrolled 4×4 panels with explicit
//! accumulators the compiler autovectorizes) plus a blocked generic
//! fallback for arbitrary shapes. The real-mode blocked execution path and
//! the PJRT-less tests run on these.
//!
//! All kernels compute `C += A · B` with row-major blocks.

/// C += A·B, row-major, dims (m × k)·(k × n).
pub fn smm(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // dispatch to specialized kernels for the artifact sizes
    match (m, n, k) {
        (4, 4, 4) => smm_fixed::<4>(a, b, c),
        (8, 8, 8) => smm_fixed::<8>(a, b, c),
        (16, 16, 16) => smm_fixed::<16>(a, b, c),
        (22, 22, 22) => smm_fixed::<22>(a, b, c),
        (32, 32, 32) => smm_fixed::<32>(a, b, c),
        (48, 48, 48) => smm_fixed::<48>(a, b, c),
        (64, 64, 64) => smm_fixed::<64>(a, b, c),
        (80, 80, 80) => smm_fixed::<80>(a, b, c),
        _ => smm_generic(m, n, k, a, b, c),
    }
}

/// Square kernel with compile-time dimension: the i-k-j loop order keeps
/// B rows and the C row streaming; const N lets the compiler fully
/// vectorize and unroll the inner j loop.
fn smm_fixed<const N: usize>(a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..N {
        let crow = &mut c[i * N..(i + 1) * N];
        for kk in 0..N {
            let aik = a[i * N + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * N..(kk + 1) * N];
            for j in 0..N {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Generic i-k-j kernel for arbitrary (m, n, k).
pub fn smm_generic(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aik * bj;
            }
        }
    }
}

/// Blocked large GEMM on the CPU (C += A·B): tiles the i/j/k loops to keep
/// panels L1/L2-resident. Used by real-mode densified execution when the
/// PJRT backend is disabled, and as the reference in tests.
pub fn gemm_blocked(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    const TI: usize = 64;
    const TJ: usize = 256;
    const TK: usize = 64;
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i0 in (0..m).step_by(TI) {
        let i1 = (i0 + TI).min(m);
        for k0 in (0..k).step_by(TK) {
            let k1 = (k0 + TK).min(k);
            for j0 in (0..n).step_by(TJ) {
                let j1 = (j0 + TJ).min(n);
                for i in i0..i1 {
                    let crow = &mut c[i * n + j0..i * n + j1];
                    for kk in k0..k1 {
                        let aik = a[i * k + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                            *cj += aik * bj;
                        }
                    }
                }
            }
        }
    }
}

/// Naive reference (tests only).
pub fn gemm_naive(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for kk in 0..k {
                acc += a[i * k + kk] as f64 * b[kk * n + j] as f64;
            }
            c[i * n + j] += acc as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, check};
    use crate::util::rng::Rng;

    fn rand_buf(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.next_f32_sym()).collect()
    }

    #[test]
    fn fixed_kernels_match_naive() {
        for &s in &[4usize, 8, 16, 22, 32, 48, 64, 80] {
            let mut rng = Rng::new(s as u64);
            let a = rand_buf(&mut rng, s * s);
            let b = rand_buf(&mut rng, s * s);
            let mut c1 = rand_buf(&mut rng, s * s);
            let mut c2 = c1.clone();
            smm(s, s, s, &a, &b, &mut c1);
            gemm_naive(s, s, s, &a, &b, &mut c2);
            assert_allclose(&c1, &c2, 1e-4, 1e-4).unwrap_or_else(|e| panic!("s={s}: {e}"));
        }
    }

    #[test]
    fn generic_rectangular() {
        let (m, n, k) = (5, 9, 7);
        let mut rng = Rng::new(1);
        let a = rand_buf(&mut rng, m * k);
        let b = rand_buf(&mut rng, k * n);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        smm(m, n, k, &a, &b, &mut c1);
        gemm_naive(m, n, k, &a, &b, &mut c2);
        assert_allclose(&c1, &c2, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0; 4];
        let b = vec![1.0; 4];
        let mut c = vec![10.0; 4];
        smm_generic(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![12.0; 4]); // 10 + 2
    }

    #[test]
    fn blocked_gemm_matches_naive_prop() {
        check("gemm_blocked == naive", 20, |rng, size| {
            let m = rng.range(1, 8 * size.0);
            let n = rng.range(1, 8 * size.0);
            let k = rng.range(1, 8 * size.0);
            let a = rand_buf(rng, m * k);
            let b = rand_buf(rng, k * n);
            let mut c1 = rand_buf(rng, m * n);
            let mut c2 = c1.clone();
            gemm_blocked(m, n, k, &a, &b, &mut c1);
            gemm_naive(m, n, k, &a, &b, &mut c2);
            assert_allclose(&c1, &c2, 1e-3, 1e-3)
        });
    }

    #[test]
    fn smm_zero_a_is_noop() {
        let a = vec![0.0; 22 * 22];
        let b = vec![1.0; 22 * 22];
        let mut c = vec![3.0; 22 * 22];
        smm(22, 22, 22, &a, &b, &mut c);
        assert!(c.iter().all(|&x| x == 3.0));
    }
}
