//! Local-multiplication backends.
//!
//! The paper's local compute lands on three engines: LIBCUSMM (autotuned
//! GPU small-matmul), cuBLAS (large GEMM on GPU), and LIBXSMM (CPU
//! small-matmul fallback). Here:
//!
//! * [`smm_cpu`] — specialized CPU microkernels (LIBXSMM analog); also the
//!   real-mode fallback for block shapes with no AOT artifact.
//! * [`gpu_sim`] — the simulated GPU device: memory pool, pinned staging,
//!   two streams with double buffering; numerics via the PJRT-executed
//!   Pallas artifacts (cuBLAS / LIBCUSMM analogs), timing via
//!   [`crate::perfmodel`].
//! * [`autotune`] — the LIBCUSMM parameter-tuning framework with a
//!   regression-tree performance model.
//! * [`stack`] — the stack (batch) types shared by Generation, Scheduler
//!   and the executors.

pub mod autotune;
pub mod gpu_sim;
pub mod smm_cpu;
pub mod stack;

pub use gpu_sim::GpuSim;
pub use stack::{Stack, StackEntries, StackEntry};
