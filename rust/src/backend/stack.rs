//! Stacks — the batches of small-block multiplications DBCSR schedules.
//!
//! One stack groups up to [`STACK_CAP`] multiplications `C += A·B` of
//! identical dimensions (m × k)·(k × n); entries index into the flat
//! element buffers of the A/B/C panels by element offset, exactly like
//! DBCSR's parameter stacks feed LIBCUSMM.

/// The paper's batch cap: "each batch consists of maximum 30'000
/// multiplications" (§II).
pub const STACK_CAP: usize = 30_000;

/// One multiplication in a stack: element offsets of the three blocks in
/// their panel buffers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StackEntry {
    pub a_off: usize,
    pub b_off: usize,
    pub c_off: usize,
}

/// Entry storage: explicit in real mode, a count in model mode.
#[derive(Clone, Debug)]
pub enum StackEntries {
    Real(Vec<StackEntry>),
    Model { count: usize },
}

impl StackEntries {
    pub fn len(&self) -> usize {
        match self {
            StackEntries::Real(v) => v.len(),
            StackEntries::Model { count } => *count,
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A homogeneous batch of (m × k)·(k × n) block multiplications, assigned
/// to one OpenMP-analog thread.
#[derive(Clone, Debug)]
pub struct Stack {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Owning thread (static assignment by A row-block, §II).
    pub thread: usize,
    pub entries: StackEntries,
}

impl Stack {
    /// Real FLOPs in this stack.
    pub fn flops(&self) -> u64 {
        2 * (self.m * self.n * self.k) as u64 * self.entries.len() as u64
    }

    /// Bytes staged host→device for this stack: the *parameter stack*
    /// (three offsets per entry), as in DBCSR — block data is uploaded
    /// once per tick as whole panels and reused on-device across stacks.
    pub fn h2d_bytes(&self) -> u64 {
        12 * self.entries.len() as u64
    }

    /// Bytes returned device→host per stack: none — C blocks accumulate
    /// on the device and are fetched once when the multiplication ends.
    pub fn d2h_bytes(&self) -> u64 {
        0
    }

    /// Raw block data this stack references (A+B+C), f32 bytes — used
    /// for staging-buffer sizing, not per-stack transfers.
    pub fn data_bytes(&self) -> u64 {
        let per = self.m * self.k + self.k * self.n + self.m * self.n;
        4 * per as u64 * self.entries.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_and_bytes() {
        let s = Stack {
            m: 22,
            n: 22,
            k: 22,
            thread: 0,
            entries: StackEntries::Model { count: 100 },
        };
        assert_eq!(s.flops(), 2 * 22 * 22 * 22 * 100);
        assert_eq!(s.h2d_bytes(), 12 * 100); // parameter stack only
        assert_eq!(s.d2h_bytes(), 0);
        assert_eq!(s.data_bytes(), 4 * (3 * 22 * 22) as u64 * 100);
    }

    #[test]
    fn entries_len() {
        assert_eq!(StackEntries::Model { count: 7 }.len(), 7);
        let e = StackEntries::Real(vec![StackEntry {
            a_off: 0,
            b_off: 0,
            c_off: 0,
        }]);
        assert_eq!(e.len(), 1);
        assert!(!e.is_empty());
    }
}
