//! CART regression tree — the performance-model learner.
//!
//! Greedy variance-reduction splits over the [`super::Features`] vector,
//! depth- and leaf-size-limited. Small, deterministic, no dependencies —
//! the role LIBCUSMM fills with scikit-learn regression trees.

use super::Features;

/// A fitted regression tree.
#[derive(Clone, Debug)]
pub struct RegressionTree {
    nodes: Vec<Node>,
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        /// Index of the left child; right child is `left + 1 ... ` no —
        /// children are stored at explicit indices.
        left: usize,
        right: usize,
    },
}

impl RegressionTree {
    /// Fit on (features, target) pairs.
    pub fn fit(xs: &[Features], ys: &[f64], max_depth: usize, min_leaf: usize) -> RegressionTree {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "empty training set");
        let mut nodes = Vec::new();
        let idx: Vec<usize> = (0..xs.len()).collect();
        build(&mut nodes, xs, ys, idx, max_depth, min_leaf);
        RegressionTree { nodes }
    }

    /// Predict the target for one feature vector.
    pub fn predict(&self, x: &Features) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if x.0[*feature] <= *threshold {
                        *left
                    } else {
                        *right
                    };
                }
            }
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Maximum depth of the fitted tree (root = 0).
    pub fn depth(&self) -> usize {
        fn d(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(nodes, *left).max(d(nodes, *right)),
            }
        }
        d(&self.nodes, 0)
    }
}

fn mean(ys: &[f64], idx: &[usize]) -> f64 {
    idx.iter().map(|&i| ys[i]).sum::<f64>() / idx.len() as f64
}

fn sse(ys: &[f64], idx: &[usize]) -> f64 {
    let m = mean(ys, idx);
    idx.iter().map(|&i| (ys[i] - m).powi(2)).sum()
}

/// Recursively build the subtree over `idx`, returning its node index.
fn build(
    nodes: &mut Vec<Node>,
    xs: &[Features],
    ys: &[f64],
    idx: Vec<usize>,
    depth_left: usize,
    min_leaf: usize,
) -> usize {
    let here = nodes.len();
    nodes.push(Node::Leaf { value: 0.0 }); // placeholder

    let leaf = |nodes: &mut Vec<Node>, idx: &[usize]| {
        nodes[here] = Node::Leaf {
            value: mean(ys, idx),
        };
        here
    };

    if depth_left == 0 || idx.len() < 2 * min_leaf {
        return leaf(nodes, &idx);
    }

    // best (feature, threshold) by SSE reduction
    let parent_sse = sse(ys, &idx);
    let nfeat = xs[0].0.len();
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, child_sse)
    for f in 0..nfeat {
        let mut vals: Vec<f64> = idx.iter().map(|&i| xs[i].0[f]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        // candidate thresholds: midpoints (subsampled for speed)
        let step = (vals.len() / 16).max(1);
        for w in vals.windows(2).step_by(step) {
            let thr = 0.5 * (w[0] + w[1]);
            let (l, r): (Vec<usize>, Vec<usize>) =
                idx.iter().partition(|&&i| xs[i].0[f] <= thr);
            if l.len() < min_leaf || r.len() < min_leaf {
                continue;
            }
            let child = sse(ys, &l) + sse(ys, &r);
            if best.map_or(true, |(_, _, b)| child < b) {
                best = Some((f, thr, child));
            }
        }
    }

    match best {
        Some((f, thr, child_sse)) if child_sse < parent_sse * 0.999 => {
            let (l, r): (Vec<usize>, Vec<usize>) = idx.iter().partition(|&&i| xs[i].0[f] <= thr);
            let left = build(nodes, xs, ys, l, depth_left - 1, min_leaf);
            let right = build(nodes, xs, ys, r, depth_left - 1, min_leaf);
            nodes[here] = Node::Split {
                feature: f,
                threshold: thr,
                left,
                right,
            };
            here
        }
        _ => leaf(nodes, &idx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f1(x: f64) -> Features {
        Features([x, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])
    }

    #[test]
    fn fits_step_function() {
        let xs: Vec<Features> = (0..100).map(|i| f1(i as f64)).collect();
        let ys: Vec<f64> = (0..100).map(|i| if i < 50 { 1.0 } else { 5.0 }).collect();
        let t = RegressionTree::fit(&xs, &ys, 4, 2);
        assert!((t.predict(&f1(10.0)) - 1.0).abs() < 0.2);
        assert!((t.predict(&f1(90.0)) - 5.0).abs() < 0.2);
    }

    #[test]
    fn fits_multifeature_interaction() {
        // y = x0 if x1 <= 0.5 else 10 - x0
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..20 {
            for j in 0..2 {
                let x0 = i as f64 / 2.0;
                let x1 = j as f64;
                xs.push(Features([x0, x1, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]));
                ys.push(if x1 <= 0.5 { x0 } else { 10.0 - x0 });
            }
        }
        let t = RegressionTree::fit(&xs, &ys, 8, 1);
        let err: f64 = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| (t.predict(x) - y).abs())
            .sum::<f64>()
            / xs.len() as f64;
        assert!(err < 1.0, "mean abs err {err}");
    }

    #[test]
    fn constant_target_single_leaf() {
        let xs: Vec<Features> = (0..10).map(|i| f1(i as f64)).collect();
        let ys = vec![3.0; 10];
        let t = RegressionTree::fit(&xs, &ys, 5, 1);
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.predict(&f1(4.0)), 3.0);
    }

    #[test]
    fn depth_limit_respected() {
        let xs: Vec<Features> = (0..64).map(|i| f1(i as f64)).collect();
        let ys: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let t = RegressionTree::fit(&xs, &ys, 3, 1);
        assert!(t.depth() <= 3);
    }

    #[test]
    fn min_leaf_respected() {
        let xs: Vec<Features> = (0..10).map(|i| f1(i as f64)).collect();
        let ys: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let t = RegressionTree::fit(&xs, &ys, 10, 5);
        // with min_leaf 5, at most one split of 10 points
        assert!(t.node_count() <= 3);
    }
}
