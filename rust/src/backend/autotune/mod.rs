//! Kernel autotuning — the LIBCUSMM analog.
//!
//! LIBCUSMM parametrizes its CUDA kernels over 7 knobs (~30k–150k combos
//! per (m,n,k)), measures a training subset, and fits a regression-tree
//! performance model over hand-engineered features to predict the rest
//! (§II). The TPU rethink keeps the same *structure* over the Pallas SMM
//! kernel's knobs ([`ParamSet`]: grouping, unroll strategy, host padding):
//!
//! 1. [`param_space`] enumerates the candidate parameter sets;
//! 2. [`measure`] scores a candidate — an analytic device model built from
//!    the kernel's VMEM footprint, MXU-utilization estimate and per-launch
//!    overheads (interpret-mode wallclock is CPU time, not a TPU proxy, so
//!    the analytic estimate *is* the measurement on this testbed);
//! 3. [`tree::RegressionTree`] learns measured-GFLOPs from
//!    [`Features`] on a training subset of sizes;
//! 4. [`Autotuner::tune`] picks the winner per (m,n,k) — measured for
//!    training sizes, model-predicted otherwise — and emits the table
//!    baked into `python/compile/aot.py`.

pub mod tree;

use crate::perfmodel::PerfModel;
use crate::util::json::{obj, Json};

pub use tree::RegressionTree;

/// Tunable parameters of one SMM kernel instantiation (mirrors
/// `python/compile/kernels/smm.py::SmmParams`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParamSet {
    /// Stack entries resident in VMEM per grid step.
    pub grouping: usize,
    /// 1 → folded batch contraction, 0 → fori loop per entry.
    pub unroll: usize,
    /// Host-side zero-padding targets (0 = natural dim).
    pub pad_m: usize,
    pub pad_n: usize,
    pub pad_k: usize,
}

impl ParamSet {
    pub fn padded(&self, m: usize, n: usize, k: usize) -> (usize, usize, usize) {
        (m.max(self.pad_m), n.max(self.pad_n), k.max(self.pad_k))
    }

    /// VMEM bytes per grid step (mirrors smm.py::vmem_bytes).
    pub fn vmem_bytes(&self, m: usize, n: usize, k: usize) -> u64 {
        let (mp, np, kp) = self.padded(m, n, k);
        4 * self.grouping as u64 * (mp * kp + kp * np + 2 * mp * np) as u64
    }

    /// MXU utilization estimate (mirrors smm.py::mxu_efficiency).
    pub fn mxu_efficiency(&self, m: usize, n: usize, k: usize) -> f64 {
        let (mp, np, kp) = self.padded(m, n, k);
        let pad = |x: usize, q: usize| x.div_ceil(q) * q;
        let real = (m * n * k) as f64;
        let padded = (pad(mp, 8) * pad(np, 128) * pad(kp, 128)) as f64;
        let fill = if self.unroll == 1 {
            (self.grouping * kp) as f64 / ((self.grouping * kp) as f64 + 128.0)
        } else {
            kp as f64 / (kp as f64 + 128.0)
        };
        (real / padded * fill * 4.0).min(1.0)
    }
}

/// TPU VMEM capacity budget for one grid step's working set.
pub const VMEM_BUDGET: u64 = 16 << 20;

/// Enumerate the parameter space for one (m, n, k).
pub fn param_space(m: usize, n: usize, k: usize) -> Vec<ParamSet> {
    let round = |x: usize, q: usize| x.div_ceil(q) * q;
    let mut out = Vec::new();
    for &grouping in &[4usize, 8, 16, 32, 64, 128] {
        for &unroll in &[0usize, 1] {
            for &pad in &[0usize, 8, 16] {
                let p = ParamSet {
                    grouping,
                    unroll,
                    pad_m: if pad == 0 { 0 } else { round(m, pad) },
                    pad_n: if pad == 0 { 0 } else { round(n, pad) },
                    pad_k: if pad == 0 { 0 } else { round(k, pad) },
                };
                if !out.contains(&p) {
                    out.push(p);
                }
            }
        }
    }
    out
}

/// "Measure" a candidate: analytic GFLOP/s on the modeled device.
///
/// Scoring terms: MXU utilization × device peak, de-rated by grid-step
/// launch amortization (small groupings launch more steps) and by VMEM
/// pressure (working sets near/over budget throttle the pipeline to
/// serial HBM reloads). Padding trades MXU packing against wasted FLOPs.
pub fn measure(perf: &PerfModel, m: usize, n: usize, k: usize, p: &ParamSet) -> f64 {
    let vmem = p.vmem_bytes(m, n, k);
    let mxu = p.mxu_efficiency(m, n, k);
    // grid-step overhead amortization: fixed per-step cost vs step work
    let step_flops = 2.0 * (p.grouping * m * n * k) as f64;
    let step_seconds_overhead = 0.8e-6;
    let ideal_rate = perf.gpu_peak * mxu;
    let step_seconds = step_flops / ideal_rate + step_seconds_overhead;
    // VMEM pressure: over ~half budget the double buffering degrades;
    // over budget the kernel spills and crawls
    let pressure = vmem as f64 / VMEM_BUDGET as f64;
    let derate = if pressure > 1.0 {
        0.1
    } else if pressure > 0.5 {
        1.0 - 0.6 * (pressure - 0.5)
    } else {
        1.0
    };
    (step_flops / step_seconds) * derate / 1e9
}

/// Feature vector for the performance model (hand-engineered, as §II).
#[derive(Clone, Copy, Debug)]
pub struct Features(pub [f64; 8]);

pub fn features(m: usize, n: usize, k: usize, p: &ParamSet) -> Features {
    let (mp, np, kp) = p.padded(m, n, k);
    Features([
        m as f64,
        k as f64,
        ((m * n * k) as f64).cbrt(),
        p.grouping as f64,
        p.unroll as f64,
        (mp * np * kp) as f64 / (m * n * k) as f64, // pad waste
        p.vmem_bytes(m, n, k) as f64 / VMEM_BUDGET as f64,
        p.mxu_efficiency(m, n, k),
    ])
}

/// The tuned winner for one block size.
#[derive(Clone, Debug)]
pub struct Tuned {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub params: ParamSet,
    pub gflops: f64,
    /// true → exhaustively measured; false → model-predicted.
    pub measured: bool,
}

/// The LIBCUSMM-analog tuner.
pub struct Autotuner {
    pub perf: PerfModel,
    pub model: Option<RegressionTree>,
}

impl Autotuner {
    pub fn new(perf: PerfModel) -> Autotuner {
        Autotuner { perf, model: None }
    }

    /// Exhaustively measure one size; returns the winner.
    pub fn tune_exhaustive(&self, m: usize, n: usize, k: usize) -> Tuned {
        let (best, gf) = param_space(m, n, k)
            .into_iter()
            .map(|p| {
                let gf = measure(&self.perf, m, n, k, &p);
                (p, gf)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("non-empty space");
        Tuned {
            m,
            n,
            k,
            params: best,
            gflops: gf,
            measured: true,
        }
    }

    /// Fit the regression-tree model from measurements on `train_sizes`.
    pub fn fit(&mut self, train_sizes: &[(usize, usize, usize)]) {
        let mut xs: Vec<Features> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for &(m, n, k) in train_sizes {
            for p in param_space(m, n, k) {
                xs.push(features(m, n, k, &p));
                ys.push(measure(&self.perf, m, n, k, &p));
            }
        }
        self.model = Some(RegressionTree::fit(&xs, &ys, 8, 4));
    }

    /// Pick the winner for one size using the fitted model (no
    /// "measurement" of this size — the LIBCUSMM prediction path).
    pub fn tune_predicted(&self, m: usize, n: usize, k: usize) -> Tuned {
        let model = self.model.as_ref().expect("call fit() first");
        let (best, pred) = param_space(m, n, k)
            .into_iter()
            .map(|p| {
                let yhat = model.predict(&features(m, n, k, &p));
                (p, yhat)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("non-empty space");
        Tuned {
            m,
            n,
            k,
            params: best,
            gflops: pred,
            measured: false,
        }
    }

    /// Tune a set of sizes: measure the training subset, predict the rest.
    pub fn tune(&mut self, sizes: &[(usize, usize, usize)], train_every: usize) -> Vec<Tuned> {
        let train: Vec<(usize, usize, usize)> = sizes
            .iter()
            .step_by(train_every.max(1))
            .copied()
            .collect();
        self.fit(&train);
        sizes
            .iter()
            .map(|&(m, n, k)| {
                if train.contains(&(m, n, k)) {
                    self.tune_exhaustive(m, n, k)
                } else {
                    self.tune_predicted(m, n, k)
                }
            })
            .collect()
    }
}

/// Serialize a tuned table (consumed by `aot.py` regeneration).
pub fn tuned_to_json(tuned: &[Tuned]) -> Json {
    Json::Arr(
        tuned
            .iter()
            .map(|t| {
                obj([
                    ("m", t.m.into()),
                    ("n", t.n.into()),
                    ("k", t.k.into()),
                    ("grouping", t.params.grouping.into()),
                    ("unroll", t.params.unroll.into()),
                    ("pad_m", t.params.pad_m.into()),
                    ("pad_n", t.params.pad_n.into()),
                    ("pad_k", t.params.pad_k.into()),
                    ("gflops", t.gflops.into()),
                    ("measured", t.measured.into()),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_is_substantial_and_unique() {
        let space = param_space(22, 22, 22);
        assert!(space.len() >= 20, "space too small: {}", space.len());
        for (i, a) in space.iter().enumerate() {
            assert!(!space[i + 1..].contains(a), "duplicate {a:?}");
        }
    }

    #[test]
    fn measure_penalizes_vmem_overflow() {
        let perf = PerfModel::default();
        let small = ParamSet {
            grouping: 8,
            unroll: 1,
            pad_m: 0,
            pad_n: 0,
            pad_k: 0,
        };
        let huge = ParamSet {
            grouping: 128 * 64,
            ..small
        };
        assert!(huge.vmem_bytes(80, 80, 80) > VMEM_BUDGET);
        assert!(
            measure(&perf, 80, 80, 80, &small) > measure(&perf, 80, 80, 80, &huge),
            "overflowing VMEM must lose"
        );
    }

    #[test]
    fn exhaustive_picks_feasible_winner() {
        let tuner = Autotuner::new(PerfModel::default());
        for &s in &[4usize, 22, 64] {
            let t = tuner.tune_exhaustive(s, s, s);
            assert!(t.params.vmem_bytes(s, s, s) <= VMEM_BUDGET);
            assert!(t.gflops > 0.0);
        }
    }

    #[test]
    fn bigger_blocks_tune_to_higher_gflops() {
        let tuner = Autotuner::new(PerfModel::default());
        let t4 = tuner.tune_exhaustive(4, 4, 4);
        let t64 = tuner.tune_exhaustive(64, 64, 64);
        assert!(t64.gflops > t4.gflops);
    }

    #[test]
    fn model_predictions_close_to_truth() {
        // LIBCUSMM property: the model trained on a subset picks params
        // achieving most of the exhaustive winner's throughput elsewhere.
        let mut tuner = Autotuner::new(PerfModel::default());
        let train: Vec<(usize, usize, usize)> =
            [4usize, 8, 16, 32, 48, 80].iter().map(|&s| (s, s, s)).collect();
        tuner.fit(&train);
        for &s in &[22usize, 64] {
            let predicted = tuner.tune_predicted(s, s, s);
            let truth = tuner.tune_exhaustive(s, s, s);
            let achieved = measure(&tuner.perf, s, s, s, &predicted.params);
            assert!(
                achieved >= 0.7 * truth.gflops,
                "size {s}: predicted params achieve {achieved} vs best {}",
                truth.gflops
            );
        }
    }

    #[test]
    fn tune_mixes_measured_and_predicted() {
        let mut tuner = Autotuner::new(PerfModel::default());
        let sizes: Vec<(usize, usize, usize)> =
            [4usize, 8, 16, 22, 32, 48, 64, 80].iter().map(|&s| (s, s, s)).collect();
        let tuned = tuner.tune(&sizes, 2);
        assert_eq!(tuned.len(), 8);
        assert!(tuned.iter().any(|t| t.measured));
        assert!(tuned.iter().any(|t| !t.measured));
    }

    #[test]
    fn json_emission_roundtrips() {
        let tuner = Autotuner::new(PerfModel::default());
        let t = tuner.tune_exhaustive(22, 22, 22);
        let j = tuned_to_json(&[t.clone()]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.idx(0).get("m").as_usize(), Some(22));
        assert_eq!(
            parsed.idx(0).get("grouping").as_usize(),
            Some(t.params.grouping)
        );
    }
}
