//! Figure regenerators — one function per table/figure of §IV.
//!
//! Every function returns [`Table`]s whose rows mirror the paper's bars /
//! series; the experiment index in DESIGN.md §6 maps each to its bench
//! target. `scale` divides the paper's matrix dimensions (1 = paper
//! scale in model mode; benches also run reduced real-mode points).

use crate::dist::{NetModel, Transport};
use crate::matrix::Mode;
use crate::perfmodel::PerfModel;

use super::harness::{run_spec, AlgoSpec, Engine, RunSpec, Shape};
use super::table::{fmt_secs, Table};

/// The paper's Fig. 2 node sweep (square rank counts for every grid
/// config; the 1×12 @ 16-node point is the OOM annotation).
pub const FIG2_NODES: [usize; 4] = [16, 25, 36, 64];
/// Fig. 3 / Fig. 4 node sweep at the optimal 4×3 config (P = 4·nodes).
pub const FIG34_NODES: [usize; 4] = [16, 25, 36, 64];
/// The grid configurations of Fig. 2 as (ranks, threads).
pub const GRID_CONFIGS: [(usize, usize); 4] = [(4, 3), (1, 12), (12, 1), (6, 2)];

fn shape_for(square: bool, scale: usize) -> Shape {
    if square {
        Shape::paper_square().scaled(scale)
    } else {
        Shape::paper_rect().scaled(scale)
    }
}

/// E1/E8 — Fig. 2: densified square multiplication across grid configs.
/// Returns one table per block size (22, 64).
pub fn fig2(scale: usize, mode: Mode) -> Vec<Table> {
    let mut tables = Vec::new();
    for &block in &[22usize, 64] {
        let mut t = Table::new(
            format!("Fig.2({}) grid config sweep, densified square, block {block}",
                if block == 22 { "a" } else { "b" }),
            &["nodes", "4x3", "1x12", "12x1", "6x2", "best", "worst/best"],
        );
        for &nodes in &FIG2_NODES {
            let mut cells = vec![nodes.to_string()];
            let mut times = Vec::new();
            for &(rpn, threads) in &[(4, 3), (1, 12), (12, 1), (6, 2)] {
                let r = run_spec(RunSpec {
                    nodes,
                    rpn,
                    threads,
                    block,
                    shape: shape_for(true, scale),
                    engine: Engine::DbcsrDensified,
                    mode,
                    net: NetModel::aries(rpn),
                    transport: Transport::TwoSided,
                    overlap: false,
                    algo: AlgoSpec::Layout,
                    plan_verbose: false,
                    occupancy: 1.0,
                    iterations: 1,
                    fault: None,
                    faultnet: None,
                    fault_policy: Default::default(),
                    spares: 0,
                });
                cells.push(fmt_secs(r.seconds));
                if !r.oom {
                    times.push(r.seconds);
                }
            }
            let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
            let worst = times.iter().cloned().fold(0.0f64, f64::max);
            cells.push(fmt_secs(best));
            cells.push(format!("{:.2}x", worst / best));
            t.row(cells);
        }
        tables.push(t);
    }
    tables
}

/// E2/E3 — Fig. 3: T_blocked / T_densified ratios.
pub fn fig3(scale: usize, mode: Mode) -> Vec<Table> {
    let mut tables = Vec::new();
    for &square in &[true, false] {
        let label = if square { "a) square" } else { "b) rectangular" };
        let mut t = Table::new(
            format!("Fig.3({label}) blocked/densified ratio"),
            &["nodes", "b22 blocked", "b22 dens", "b22 ratio", "b64 blocked", "b64 dens", "b64 ratio"],
        );
        for &nodes in &FIG34_NODES {
            let mut cells = vec![nodes.to_string()];
            for &block in &[22usize, 64] {
                let mut pair = Vec::new();
                for &engine in &[Engine::DbcsrBlocked, Engine::DbcsrDensified] {
                    let r = run_spec(RunSpec {
                        nodes,
                        rpn: 4,
                        threads: 3,
                        block,
                        shape: shape_for(square, scale),
                        engine,
                        mode,
                        net: NetModel::aries(4),
                        transport: Transport::TwoSided,
                        overlap: false,
                        algo: AlgoSpec::Layout,
                        plan_verbose: false,
                        occupancy: 1.0,
                        iterations: 1,
                        fault: None,
                        faultnet: None,
                        fault_policy: Default::default(),
                        spares: 0,
                    });
                    pair.push(r.seconds);
                }
                cells.push(fmt_secs(pair[0]));
                cells.push(fmt_secs(pair[1]));
                cells.push(if pair[0] > 0.0 && pair[1] > 0.0 {
                    format!("{:.2}", pair[0] / pair[1])
                } else {
                    "OOM".into()
                });
            }
            t.row(cells);
        }
        tables.push(t);
    }
    tables
}

/// E4/E5/E6 — Fig. 4: T_PDGEMM / T_DBCSR(densified) ratios.
/// `blocks` defaults to [22, 64]; pass `[4]` + `square_only` for the
/// §IV-C small-block test (E6 — the paper reports the square case only).
pub fn fig4(scale: usize, mode: Mode, blocks: &[usize], square_only: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    let shapes: &[bool] = if square_only { &[true] } else { &[true, false] };
    for &square in shapes {
        let label = if square { "a) square" } else { "b) rectangular" };
        let mut headers: Vec<String> = vec!["nodes".into()];
        for b in blocks {
            headers.push(format!("b{b} pdgemm"));
            headers.push(format!("b{b} dbcsr"));
            headers.push(format!("b{b} ratio"));
        }
        let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(format!("Fig.4({label}) PDGEMM/DBCSR ratio"), &href);
        for &nodes in &FIG34_NODES {
            let mut cells = vec![nodes.to_string()];
            for &block in blocks {
                let mut pair = Vec::new();
                for &engine in &[Engine::Pdgemm, Engine::DbcsrDensified] {
                    let r = run_spec(RunSpec {
                        nodes,
                        rpn: 4,
                        threads: 3,
                        block,
                        shape: shape_for(square, scale),
                        engine,
                        mode,
                        net: NetModel::aries(4),
                        transport: Transport::TwoSided,
                        overlap: false,
                        algo: AlgoSpec::Layout,
                        plan_verbose: false,
                        occupancy: 1.0,
                        iterations: 1,
                        fault: None,
                        faultnet: None,
                        fault_policy: Default::default(),
                        spares: 0,
                    });
                    pair.push(r.seconds);
                }
                cells.push(fmt_secs(pair[0]));
                cells.push(fmt_secs(pair[1]));
                cells.push(if pair[0] > 0.0 && pair[1] > 0.0 {
                    format!("{:.2}", pair[0] / pair[1])
                } else {
                    "OOM".into()
                });
            }
            t.row(cells);
        }
        tables.push(t);
    }
    tables
}

/// E7 — §II: the LIBCUSMM-analog vs batched-cuBLAS-analog speedup curve
/// (2–4× below 32, fading to ~1 by 80).
pub fn smm_speedup() -> Table {
    let perf = PerfModel::default();
    let mut t = Table::new(
        "§II LIBCUSMM vs batched-cuBLAS speedup (SMM autotune curve)",
        &["block", "smm GF/s", "cublas-batched GF/s", "speedup"],
    );
    for &b in &[4usize, 8, 16, 22, 32, 48, 64, 80] {
        let smm = perf.gpu_peak * perf.smm_efficiency(b) / 1e9;
        let cub = perf.gpu_peak * perf.cublas_batched_efficiency(b) / 1e9;
        t.row(vec![
            b.to_string(),
            format!("{smm:.0}"),
            format!("{cub:.0}"),
            format!("{:.2}x", smm / cub),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny-scale smoke of every figure path (full scale runs in benches).
    #[test]
    fn fig3_small_scale_shapes_hold() {
        let tables = fig3(22, Mode::Model); // square 2880, rect 64/90112→ scaled
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), FIG34_NODES.len());
    }

    #[test]
    fn smm_speedup_curve_matches_paper_claim() {
        let t = smm_speedup();
        let ratio = |row: usize| {
            t.rows[row][3]
                .trim_end_matches('x')
                .parse::<f64>()
                .unwrap()
        };
        // {m,n,k} < 32 → 2–4x
        assert!(ratio(0) >= 2.0 && ratio(0) <= 4.2, "b4: {}", ratio(0));
        assert!(ratio(3) >= 1.9, "b22: {}", ratio(3));
        // saturates by 80
        assert!(ratio(7) < 1.2, "b80: {}", ratio(7));
    }
}
