//! Benchmark harness: workload generation and regeneration of every table
//! and figure in the paper's evaluation (§IV).
//!
//! * [`harness`] — `RunSpec` (one experiment point: nodes × grid config ×
//!   shape × block size × engine options) and the runner that executes it
//!   over the threads-as-ranks substrate, in model mode at paper scale or
//!   real mode at reduced scale.
//! * [`figures`] — the per-figure sweeps: Fig. 2 (grid configuration),
//!   Fig. 3 (blocked vs densified), Fig. 4 (PDGEMM vs DBCSR), and the
//!   §II LIBCUSMM-vs-batched-cuBLAS curve (E7).
//! * [`table`] — plain-text/JSON table output.

pub mod figures;
pub mod harness;
pub mod table;
