//! Experiment runner: one `RunSpec` = one bar/point of a paper figure.

use crate::dist::{run_ranks, NetModel, Transport};
use crate::matrix::matrix::Fill;
use crate::matrix::{DistMatrix, Mode};
use crate::multiply::{multiply, tall_skinny, Algorithm, EngineOpts, MultiplyConfig};
use crate::perfmodel::PerfModel;
use crate::scalapack::pdgemm;
use crate::util::stats::MultiplyStats;

/// Matrix shape of the workload (§IV): square or tall-and-skinny.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// M = N = K = n ("square matrix", paper: 63 360).
    Square { n: usize },
    /// M = N = mn, K = k ("rectangular", paper: 1 408 / 1 982 464).
    Rect { mn: usize, k: usize },
}

impl Shape {
    /// The paper's square workload.
    pub fn paper_square() -> Shape {
        Shape::Square { n: 63_360 }
    }
    /// The paper's rectangular workload.
    pub fn paper_rect() -> Shape {
        Shape::Rect {
            mn: 1_408,
            k: 1_982_464,
        }
    }
    /// Scaled-down versions for real-mode runs / fast sweeps.
    pub fn scaled(self, factor: usize) -> Shape {
        match self {
            Shape::Square { n } => Shape::Square { n: n / factor },
            Shape::Rect { mn, k } => Shape::Rect {
                mn: mn / factor,
                k: k / factor,
            },
        }
    }
    pub fn dims(self) -> (usize, usize, usize) {
        match self {
            Shape::Square { n } => (n, n, n),
            Shape::Rect { mn, k } => (mn, mn, k),
        }
    }
}

/// Which multiplication runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// DBCSR with densification (§III).
    DbcsrDensified,
    /// DBCSR blocked.
    DbcsrBlocked,
    /// The ScaLAPACK-style PDGEMM baseline.
    Pdgemm,
}

/// One experiment point.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    pub nodes: usize,
    /// MPI ranks per node (grid config first factor).
    pub rpn: usize,
    /// OpenMP-analog threads per rank (second factor).
    pub threads: usize,
    pub block: usize,
    pub shape: Shape,
    pub engine: Engine,
    pub mode: Mode,
    /// Fabric model driving the virtual clocks (sweeps can compare
    /// `NetModel::ideal()` against `NetModel::aries(rpn)`).
    pub net: NetModel,
    /// Point-to-point transport (two-sided sendrecv vs one-sided RMA).
    pub transport: Transport,
}

/// Result of one experiment point (aggregated over ranks).
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Virtual completion time: max over ranks (negative ⇒ OOM).
    pub seconds: f64,
    /// Wallclock of the whole simulation (testbed time, not the metric).
    pub wall: f64,
    pub stats: MultiplyStats,
    pub oom: bool,
}

/// Most-square factorization pr × pc = p with pr ≤ pc.
pub fn grid_shape(p: usize) -> (usize, usize) {
    let mut pr = (p as f64).sqrt() as usize;
    while pr > 1 && p % pr != 0 {
        pr -= 1;
    }
    (pr.max(1), p / pr.max(1))
}

/// Execute one experiment point.
pub fn run_spec(spec: RunSpec) -> RunResult {
    let p = spec.nodes * spec.rpn;
    let (pr, pc) = grid_shape(p);
    let (m, n, k) = spec.shape.dims();
    let net = spec.net;
    let is_rect = matches!(spec.shape, Shape::Rect { .. });
    let wall0 = std::time::Instant::now();

    let per_rank = run_ranks(p, net, move |world| {
        let cfg = MultiplyConfig {
            engine: EngineOpts {
                threads: spec.threads,
                densify: spec.engine == Engine::DbcsrDensified,
                ..Default::default()
            },
            perf: PerfModel::default(),
            algorithm: if is_rect && spec.engine != Engine::Pdgemm {
                Algorithm::TallSkinny
            } else {
                Algorithm::Cannon
            },
            transport: spec.transport,
            gpu_share: spec.rpn,
            runtime: None,
        };
        let outcome = if is_rect && spec.engine != Engine::Pdgemm {
            // tall-skinny operand layout (K 1-D over all ranks)
            let (a, b) =
                tall_skinny::ts_operands(m, n, k, spec.block, &world, spec.mode, 101, 102);
            let grid = crate::dist::Grid2D::new(world, 1, p);
            multiply(&grid, &a, &b, &cfg)
        } else {
            let grid = crate::dist::Grid2D::new(world, pr, pc);
            let coords = grid.coords();
            let a = DistMatrix::dense_cyclic(
                m,
                k,
                spec.block,
                (pr, pc),
                coords,
                spec.mode,
                fill_for(spec.mode, 101),
            );
            let b = DistMatrix::dense_cyclic(
                k,
                n,
                spec.block,
                (pr, pc),
                coords,
                spec.mode,
                fill_for(spec.mode, 102),
            );
            if spec.engine == Engine::Pdgemm {
                pdgemm(&grid, &a, &b, &cfg)
            } else {
                multiply(&grid, &a, &b, &cfg)
            }
        };
        match outcome {
            Ok(o) => (o.virtual_seconds, o.stats, false),
            Err(_) => (0.0, MultiplyStats::default(), true),
        }
    });

    let mut stats = MultiplyStats::default();
    let mut seconds = 0.0f64;
    let mut oom = false;
    for (t, s, rank_oom) in per_rank {
        seconds = seconds.max(t);
        stats.merge(&s);
        oom |= rank_oom;
    }
    RunResult {
        seconds: if oom { -1.0 } else { seconds },
        wall: wall0.elapsed().as_secs_f64(),
        stats,
        oom,
    }
}

fn fill_for(mode: Mode, seed: u64) -> Fill {
    match mode {
        Mode::Real => Fill::Random { seed },
        Mode::Model => Fill::Zero,
    }
}

/// Overload for tall-skinny operand construction: (m, k) with N = m.
pub mod tshelp {
    use super::*;
    use crate::dist::CommView;

    pub fn operands(
        m: usize,
        k: usize,
        block: usize,
        world: &CommView,
        mode: Mode,
        sa: u64,
        sb: u64,
    ) -> (DistMatrix, DistMatrix) {
        tall_skinny::ts_operands(m, m, k, block, world, mode, sa, sb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_most_square() {
        assert_eq!(grid_shape(16), (4, 4));
        assert_eq!(grid_shape(24), (4, 6));
        assert_eq!(grid_shape(192), (12, 16));
        assert_eq!(grid_shape(1), (1, 1));
        assert_eq!(grid_shape(7), (1, 7));
    }

    #[test]
    fn shapes() {
        assert_eq!(Shape::paper_square().dims(), (63_360, 63_360, 63_360));
        let (m, n, k) = Shape::paper_rect().dims();
        assert_eq!((m, n), (1_408, 1_408));
        assert_eq!(k, 1_982_464);
        assert_eq!(Shape::Square { n: 64 }.scaled(2).dims().0, 32);
    }

    #[test]
    fn model_point_square_densified() {
        let r = run_spec(RunSpec {
            nodes: 1,
            rpn: 4,
            threads: 3,
            block: 22,
            shape: Shape::Square { n: 2816 },
            engine: Engine::DbcsrDensified,
            mode: Mode::Model,
            net: NetModel::aries(4),
            transport: Transport::TwoSided,
        });
        assert!(!r.oom);
        assert!(r.seconds > 0.0);
        assert!(r.stats.flops > 0);
    }

    #[test]
    fn model_point_rect_ts() {
        let r = run_spec(RunSpec {
            nodes: 1,
            rpn: 4,
            threads: 3,
            block: 22,
            shape: Shape::Rect { mn: 352, k: 22528 },
            engine: Engine::DbcsrDensified,
            mode: Mode::Model,
            net: NetModel::aries(4),
            transport: Transport::TwoSided,
        });
        assert!(!r.oom && r.seconds > 0.0);
    }

    #[test]
    fn model_point_pdgemm() {
        let r = run_spec(RunSpec {
            nodes: 1,
            rpn: 4,
            threads: 3,
            block: 22,
            shape: Shape::Square { n: 2816 },
            engine: Engine::Pdgemm,
            mode: Mode::Model,
            net: NetModel::aries(4),
            transport: Transport::TwoSided,
        });
        assert!(!r.oom && r.seconds > 0.0);
    }

    #[test]
    fn net_model_comes_from_the_spec() {
        // regression: the harness used to hardcode NetModel::aries(rpn);
        // an ideal-fabric sweep must show zero comm wait and run faster
        let point = |net: NetModel| {
            run_spec(RunSpec {
                nodes: 1,
                rpn: 4,
                threads: 3,
                block: 22,
                shape: Shape::Square { n: 1408 },
                engine: Engine::DbcsrDensified,
                mode: Mode::Model,
                net,
                transport: Transport::TwoSided,
            })
        };
        let aries = point(NetModel::aries(4));
        let ideal = point(NetModel::ideal());
        assert!(ideal.stats.comm_wait_s == 0.0, "{}", ideal.stats.comm_wait_s);
        assert!(aries.stats.comm_wait_s > 0.0);
        assert!(ideal.seconds < aries.seconds);
        assert_eq!(ideal.stats.comm_bytes, aries.stats.comm_bytes);
    }

    #[test]
    fn one_sided_transport_sweeps_through_the_harness() {
        let point = |transport: Transport| {
            run_spec(RunSpec {
                nodes: 4,
                rpn: 4,
                threads: 3,
                block: 22,
                shape: Shape::Square { n: 1408 },
                engine: Engine::DbcsrDensified,
                mode: Mode::Model,
                net: NetModel::aries(4),
                transport,
            })
        };
        let two = point(Transport::TwoSided);
        let one = point(Transport::OneSided);
        assert_eq!(two.stats.comm_bytes, one.stats.comm_bytes);
        assert!(
            one.stats.comm_wait_s < two.stats.comm_wait_s,
            "one-sided must lower comm wait ({} vs {})",
            one.stats.comm_wait_s,
            two.stats.comm_wait_s
        );
    }

    #[test]
    fn real_point_matches_model_counters() {
        let spec = |mode| RunSpec {
            nodes: 1,
            rpn: 4,
            threads: 2,
            block: 8,
            shape: Shape::Square { n: 64 },
            engine: Engine::DbcsrBlocked,
            mode,
            net: NetModel::aries(4),
            transport: Transport::TwoSided,
        };
        let r = run_spec(spec(Mode::Real));
        let m = run_spec(spec(Mode::Model));
        assert_eq!(r.stats.block_mults, m.stats.block_mults);
        assert_eq!(r.stats.stacks, m.stats.stacks);
    }
}
