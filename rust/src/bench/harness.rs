//! Experiment runner: one `RunSpec` = one bar/point of a paper figure.
//!
//! [`RunSpec::algo`] selects how the data-exchange algorithm is chosen:
//! the pre-planner [`AlgoSpec::Layout`] heuristic (what the figure
//! regenerators pin), a forced Cannon / fixed-`c` 2.5D point (the
//! fixed-replication series of `bench_fig_2p5d` and the planner test
//! suite), or [`AlgoSpec::Auto`] — the model-driven path that consults
//! `multiply::planner::choose_plan` *before* operands are built, lays the
//! operands out for the chosen layer grid (replicating canonical shares
//! when `c > 1`, charged to the clocks and reported via
//! [`RunResult::repl_seconds`]), and surfaces the decision in
//! [`RunResult::plan`].

use crate::backend::gpu_sim::DeviceOom;
use crate::dist::faultnet::{FaultPlan, FaultPolicy};
use crate::dist::verify::{self, TraceLog, VerifyReport};
use crate::dist::{run_ranks_full, Grid2D, Grid3D, NetModel, RunOpts, Transport};
use crate::obs::{Lane, Phase, ProfLog};
use crate::matrix::matrix::Fill;
use crate::matrix::{BlockLayout, DistMatrix, Mode};
use crate::multiply::planner::{self, PlanInput, PlannedAlgorithm};
use crate::multiply::session::{spare_serve, PipelineSession, SpareOutcome};
use crate::multiply::twofive::replicate_to_layers;
use crate::multiply::{multiply, tall_skinny, Algorithm, EngineOpts, FaultSpec, MultiplyConfig};
use crate::perfmodel::PerfModel;
use crate::scalapack::pdgemm;
use crate::util::stats::{MultiplyStats, PlanSummary};

/// Matrix shape of the workload (§IV): square or tall-and-skinny.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// M = N = K = n ("square matrix", paper: 63 360).
    Square { n: usize },
    /// M = N = mn, K = k ("rectangular", paper: 1 408 / 1 982 464).
    Rect { mn: usize, k: usize },
}

impl Shape {
    /// The paper's square workload.
    pub fn paper_square() -> Shape {
        Shape::Square { n: 63_360 }
    }
    /// The paper's rectangular workload.
    pub fn paper_rect() -> Shape {
        Shape::Rect {
            mn: 1_408,
            k: 1_982_464,
        }
    }
    /// Scaled-down versions for real-mode runs / fast sweeps.
    pub fn scaled(self, factor: usize) -> Shape {
        match self {
            Shape::Square { n } => Shape::Square { n: n / factor },
            Shape::Rect { mn, k } => Shape::Rect {
                mn: mn / factor,
                k: k / factor,
            },
        }
    }
    pub fn dims(self) -> (usize, usize, usize) {
        match self {
            Shape::Square { n } => (n, n, n),
            Shape::Rect { mn, k } => (mn, mn, k),
        }
    }
}

/// Which multiplication runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// DBCSR with densification (§III).
    DbcsrDensified,
    /// DBCSR blocked.
    DbcsrBlocked,
    /// The ScaLAPACK-style PDGEMM baseline.
    Pdgemm,
}

/// How the data-exchange algorithm (and the 2.5D replication factor) is
/// chosen for a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoSpec {
    /// Pre-planner layout heuristic: rectangular (tall-skinny) workloads
    /// run the O(1) algorithm, everything else Cannon. The figure
    /// regenerators pin this so Fig. 2–4 semantics never shift under the
    /// planner.
    Layout,
    /// Model-driven: `planner::choose_plan` picks the replication factor
    /// from the cost model before operands are built (`c = 1` → Cannon).
    Auto,
    /// Force Cannon on the most-square grid.
    Cannon,
    /// Force the 2.5D path with a fixed replication factor; `layers = 1`
    /// degenerates to Cannon so fixed-`c` sweeps share a baseline.
    TwoFiveD { layers: usize },
}

/// One experiment point.
#[derive(Clone, Copy, Debug)]
pub struct RunSpec {
    pub nodes: usize,
    /// MPI ranks per node (grid config first factor).
    pub rpn: usize,
    /// OpenMP-analog threads per rank (second factor).
    pub threads: usize,
    pub block: usize,
    pub shape: Shape,
    pub engine: Engine,
    pub mode: Mode,
    /// Fabric model driving the virtual clocks (sweeps can compare
    /// `NetModel::ideal()` against `NetModel::aries(rpn)`).
    pub net: NetModel,
    /// Point-to-point transport (two-sided sendrecv vs one-sided RMA
    /// put vs one-sided RMA get).
    pub transport: Transport,
    /// Double-buffer the per-tick panel shifts
    /// (`MultiplyConfig::overlap`): tick `t + 1`'s transfer rides the
    /// wire while tick `t` computes; hidden transfer time lands in
    /// `MultiplyStats::overlap_hidden_s` instead of `comm_wait_s`.
    /// Results are bit-identical either way. Ignored (forced off) by
    /// fault injection and the PDGEMM / tall-skinny paths.
    pub overlap: bool,
    /// Algorithm selection policy (see [`AlgoSpec`]).
    pub algo: AlgoSpec,
    /// Thread the CLI's `--plan-verbose` into `MultiplyConfig`: rank 0
    /// prints the resolved plan + prediction from inside `multiply()`.
    pub plan_verbose: bool,
    /// Block occupancy of the operands (fraction of present blocks;
    /// 1.0 = dense, the classic paper workloads). Below 1.0 the
    /// Cannon/2.5D-family points build block-sparse operands with the
    /// deterministic [`sparse_pattern`] predicate (model mode gets
    /// pattern-accurate phantom shares), the planner prices candidates
    /// occupancy-aware, and comm volume rides the sparse wire format.
    /// The tall-skinny and PDGEMM paths are dense-only and reject
    /// sparse specs loudly.
    ///
    /// [`sparse_pattern`]: crate::matrix::sparse::sparse_pattern
    pub occupancy: f64,
    /// Steady-state knob: how many multiplies the point runs (≥ 1).
    /// At 1 every path behaves as before. At > 1 the 2.5D-family specs
    /// (`AlgoSpec::TwoFiveD`, and `Auto`, which then plans with this
    /// horizon) run a [`PipelineSession`]: operands become
    /// layer-resident once (`RunResult::repl_seconds`) and each
    /// iteration pays only the resident multiply — while
    /// `AlgoSpec::Cannon` / `Layout` loop the per-call path, staying
    /// the unamortized baseline. `RunResult::seconds` sums the
    /// iterations.
    pub iterations: usize,
    /// Chaos knob: kill one rank mid-multiply (the CLI's
    /// `--kill-rank R --kill-at T`). Requires a plan with replica
    /// layers — a fault on a Cannon / tall-skinny / `c = 1` point
    /// returns [`RunResult::unrecoverable`] without running (there is
    /// no replica to heal from). At a steady horizon the fault fires on
    /// the first resident multiply and the rank stays dead for the
    /// rest. Under [`AlgoSpec::Auto`] the planner prices the fault as
    /// one expected death, which shifts the choice toward layers.
    pub fault: Option<FaultSpec>,
    /// Adversarial-network plan (`None` = pristine fabric): every
    /// cross-rank send/put/get is perturbed per the seeded plan and
    /// healed by the reliability layer. C stays bit-identical; the
    /// wasted wire traffic lands in [`RunResult::retrans_bytes`].
    pub faultnet: Option<FaultPlan>,
    /// Response to frame failures under an active `faultnet` plan:
    /// retransmit with backoff (the default) or escalate straight to
    /// the rank-death path.
    pub fault_policy: FaultPolicy,
    /// Hot-spare ranks parked beyond the compute world
    /// (`dist::RunOpts::spares`). Requires a steady-state 2.5D point
    /// (`iterations > 1`): after the faulted first multiply the session
    /// splices the spares into the dead seats
    /// (`PipelineSession::adopt_spares`) so every later iteration runs
    /// full-width with a zero recovery bill.
    pub spares: usize,
}

impl RunSpec {
    /// The planner input equivalent to this spec (what `AlgoSpec::Auto`
    /// resolves through).
    pub fn plan_input(&self) -> PlanInput {
        let (m, n, k) = self.shape.dims();
        PlanInput {
            p: self.nodes * self.rpn,
            m,
            n,
            k,
            block: self.block,
            elem_bytes: planner::elem_bytes_for(self.mode),
            net: self.net,
            perf: PerfModel::default(),
            transport: self.transport,
            gpu_share: self.rpn,
            threads: self.threads,
            // harness runs are cold: residency setup (replication +
            // pre-skew) is paid inside the run and must be part of the
            // objective, amortized over the spec's iteration horizon
            charge_replication: true,
            horizon: self.iterations.max(1),
            overlap: self.overlap,
            occ_a: self.occupancy,
            occ_b: self.occupancy,
            // an injected fault is one certain death over the horizon —
            // priced so Auto prefers plans that can actually recover
            failure_rate: if self.fault.is_some() { 1.0 } else { 0.0 },
            recovery: planner::RecoveryModel::default(),
            spares: self.spares,
        }
    }
}

/// Result of one experiment point (aggregated over ranks).
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Virtual time of the multiplies (summed over the spec's
    /// iterations), per rank, max over ranks (negative ⇒ OOM).
    pub seconds: f64,
    /// Virtual seconds of the one-time residency setup (2.5D layer
    /// replication, plus the pre-skew for steady-state sessions); max
    /// over ranks, 0 for unreplicated runs.
    pub repl_seconds: f64,
    /// Setup + multiplies, per rank, max over ranks — the planner's
    /// objective at the spec's horizon (negative ⇒ OOM).
    pub total_seconds: f64,
    /// How many multiplies `seconds` covers (the spec's steady-state
    /// knob, clamped to ≥ 1).
    pub iterations: usize,
    /// Wallclock of the whole simulation (testbed time, not the metric).
    pub wall: f64,
    pub stats: MultiplyStats,
    /// The plan this point ran: the planner's choice under
    /// [`AlgoSpec::Auto`], otherwise whatever `multiply()` resolved.
    pub plan: Option<PlanSummary>,
    /// Achieved global occupancies, aggregated over every rank's share
    /// (operands as built; result after any filtering).
    pub occupancy_a: f64,
    pub occupancy_b: f64,
    pub occupancy_c: f64,
    pub oom: bool,
    /// Virtual seconds the survivors spent healing an injected rank
    /// death (replica-share fetches, lost-tick recompute, the recovery
    /// fence), summed over ranks. 0 on fault-free runs.
    pub recovery_seconds: f64,
    /// Wire bytes of the same recovery traffic, summed over ranks.
    pub recovery_bytes: u64,
    /// Wire bytes the reliability layer wasted on dropped, duplicated
    /// and corrupt frames plus their retransmissions, summed over
    /// ranks. 0 whenever no `faultnet` plan is active — goodput
    /// counters (`MultiplyStats::comm_bytes`) never include this.
    pub retrans_bytes: u64,
    /// Virtual seconds of the same retransmission overhead (backoffs +
    /// injected delay spikes), summed over ranks.
    pub retrans_seconds: f64,
    /// Transfer seconds the double-buffered shifts hid behind compute
    /// (`MultiplyStats::overlap_hidden_s`), summed over ranks. 0 when
    /// `overlap` is off or nothing was hidden.
    pub overlap_hidden_seconds: f64,
    /// Wire-format metadata bytes (frames, panel headers) shipped with
    /// the payload traffic, summed over ranks — the sparse-format
    /// overhead share of `comm_bytes`.
    pub meta_bytes: u64,
    /// The spec asked for a fault but resolved to a plan with no
    /// replica layer (Cannon, tall-skinny, PDGEMM, or `c = 1`): the
    /// run was not executed — a death there loses data irrecoverably,
    /// and the honest report is "restart from scratch".
    pub unrecoverable: bool,
}

/// Most-square factorization pr × pc = p with pr ≤ pc (shared with the
/// planner so candidate grids and executed grids always agree).
pub fn grid_shape(p: usize) -> (usize, usize) {
    planner::grid_shape(p)
}

/// The execution strategy a spec resolves to (internal).
#[derive(Clone, Copy)]
enum Exec {
    /// Layout heuristic: tall-skinny operands for rect shapes, Cannon
    /// grid operands otherwise (also the PDGEMM path).
    Layout,
    /// Cannon on the most-square grid.
    Cannon,
    /// Canonical 2.5D: layer-cyclic shares on `rows × cols`, replicated
    /// across `layers` in-run, then the 2.5D driver.
    TwoFive {
        rows: usize,
        cols: usize,
        layers: usize,
    },
}

/// Execute one experiment point.
pub fn run_spec(spec: RunSpec) -> RunResult {
    run_spec_opts(spec, RunOpts::default()).0
}

/// Execute one experiment point under the protocol verifier: the run is
/// traced (`dist::RunOpts::trace`), every multiply stamps a quiescence
/// boundary, and the recorded trace goes through
/// [`verify::check`]. The `RunResult` is computed exactly as in
/// [`run_spec`] — tracing never touches virtual clocks or counters.
pub fn run_spec_verified(spec: RunSpec) -> (RunResult, VerifyReport) {
    let (result, trace) = run_spec_opts(
        spec,
        RunOpts {
            trace: true,
            ..RunOpts::default()
        },
    );
    let report = verify::check(&trace.expect("traced run must return a trace"));
    (result, report)
}

/// [`run_spec`] with explicit substrate options (tracing / schedule
/// perturbation); returns the trace when tracing was on.
pub fn run_spec_opts(spec: RunSpec, opts: RunOpts) -> (RunResult, Option<TraceLog>) {
    let (result, trace, _prof) = run_spec_full(spec, opts);
    (result, trace)
}

/// [`run_spec_opts`] that also returns the span profile when
/// `RunOpts::profile` was on — the observability entry the CLI's
/// `--profile` / `--trace-out` flags go through. Profiling never
/// touches virtual clocks or counters (same contract as tracing).
pub fn run_spec_full(
    spec: RunSpec,
    opts: RunOpts,
) -> (RunResult, Option<TraceLog>, Option<ProfLog>) {
    let p = spec.nodes * spec.rpn;
    let (pr, pc) = grid_shape(p);
    let (m, n, k) = spec.shape.dims();
    let net = spec.net;
    let is_rect = matches!(spec.shape, Shape::Rect { .. });
    let wall0 = std::time::Instant::now();
    // spec-level chaos knobs override the caller's substrate options
    let mut opts = opts;
    if spec.faultnet.is_some() {
        opts.faultnet = spec.faultnet;
        opts.fault_policy = spec.fault_policy;
    }
    opts.spares = opts.spares.max(spec.spares);

    // resolve the algorithm policy (PDGEMM ignores it — the baseline has
    // exactly one data path)
    let iters = spec.iterations.max(1);
    let mut chosen_plan: Option<PlanSummary> = None;
    let exec = if spec.engine == Engine::Pdgemm {
        Exec::Layout
    } else {
        match spec.algo {
            AlgoSpec::Layout => Exec::Layout,
            AlgoSpec::Cannon => Exec::Cannon,
            AlgoSpec::TwoFiveD { layers } => {
                assert!(
                    layers > 0 && p % layers == 0,
                    "fixed layer count {layers} must divide p = {p}"
                );
                if layers == 1 && iters == 1 {
                    Exec::Cannon
                } else {
                    // at a steady horizon even c = 1 runs the resident
                    // session (its pre-skew amortizes — the planner's
                    // c = 1 steady candidate)
                    let (rows, cols) = grid_shape(p / layers);
                    Exec::TwoFive { rows, cols, layers }
                }
            }
            AlgoSpec::Auto => {
                let plan = planner::choose_plan(&spec.plan_input());
                chosen_plan = Some(plan.summary("model"));
                if iters > 1 {
                    // steady mode priced every candidate (including
                    // c = 1) as a resident session — execute it as one
                    Exec::TwoFive {
                        rows: plan.rows,
                        cols: plan.cols,
                        layers: plan.layers,
                    }
                } else {
                    match plan.algorithm {
                        PlannedAlgorithm::Cannon => Exec::Cannon,
                        PlannedAlgorithm::TwoFiveD { layers } => Exec::TwoFive {
                            rows: plan.rows,
                            cols: plan.cols,
                            layers,
                        },
                    }
                }
            }
        }
    };

    // a fault needs a replica layer to heal from; every other plan shape
    // is honestly unrecoverable — report that instead of running
    if spec.fault.is_some()
        && !matches!(exec, Exec::TwoFive { layers, .. } if layers > 1)
    {
        return (
            RunResult {
                seconds: 0.0,
                repl_seconds: 0.0,
                total_seconds: 0.0,
                iterations: iters,
                wall: wall0.elapsed().as_secs_f64(),
                stats: MultiplyStats::default(),
                plan: chosen_plan,
                occupancy_a: 0.0,
                occupancy_b: 0.0,
                occupancy_c: 0.0,
                oom: false,
                recovery_seconds: 0.0,
                recovery_bytes: 0,
                retrans_bytes: 0,
                retrans_seconds: 0.0,
                overlap_hidden_seconds: 0.0,
                meta_bytes: 0,
                unrecoverable: true,
            },
            None,
            None,
        );
    }
    if spec.spares > 0 {
        assert!(
            matches!(exec, Exec::TwoFive { .. }) && iters > 1,
            "hot spares require a steady-state 2.5D point (iterations > 1): \
             only a resident session can splice a spare into a dead seat"
        );
    }

    let (per_rank, trace, prof) = run_ranks_full(p, net, opts, move |world| {
        let wstats = world.clone();
        let cfg = |algorithm: Algorithm| MultiplyConfig {
            engine: EngineOpts {
                threads: spec.threads,
                densify: spec.engine == Engine::DbcsrDensified,
                ..Default::default()
            },
            perf: PerfModel::default(),
            algorithm,
            transport: spec.transport,
            overlap: spec.overlap,
            gpu_share: spec.rpn,
            filter_eps: 0.0,
            plan_verbose: spec.plan_verbose,
            runtime: None,
            verify: opts.trace,
            faults: spec.fault.map(|f| vec![f]).unwrap_or_default(),
        };
        // cyclic A (m × k) / B (k × n) shares over `grid_dims` — shared
        // by every grid-based branch so seeding and fill can never
        // diverge between them. Sparse specs build the deterministic
        // predicate pattern (all layers and grids agree on it); dense
        // specs keep the classic constructors bit-for-bit.
        let operands = |grid_dims: (usize, usize), coords: (usize, usize)| {
            if spec.occupancy < 1.0 {
                let mk = |rows: usize, cols: usize, seed: u64| {
                    crate::matrix::sparse::sparse_pattern(
                        crate::matrix::BlockLayout::new(rows, spec.block),
                        crate::matrix::BlockLayout::new(cols, spec.block),
                        crate::matrix::Distribution::cyclic(grid_dims.0),
                        crate::matrix::Distribution::cyclic(grid_dims.1),
                        coords,
                        spec.occupancy,
                        seed,
                        spec.mode,
                    )
                };
                return (mk(m, k, 101), mk(k, n, 102));
            }
            let a = DistMatrix::dense_cyclic(
                m,
                k,
                spec.block,
                grid_dims,
                coords,
                spec.mode,
                fill_for(spec.mode, 101),
            );
            let b = DistMatrix::dense_cyclic(
                k,
                n,
                spec.block,
                grid_dims,
                coords,
                spec.mode,
                fill_for(spec.mode, 102),
            );
            (a, b)
        };
        // run one call `iters` times with shared accounting — used by
        // the per-call baselines (multiply / PDGEMM loops) and the
        // resident-session loop alike
        let run_iters =
            |call: &mut dyn FnMut() -> Result<crate::multiply::MultiplyOutcome, DeviceOom>|
             -> (f64, MultiplyStats, bool) {
                let mut secs = 0.0f64;
                let mut stats = MultiplyStats::default();
                let mut oom = false;
                for _ in 0..iters {
                    match call() {
                        Ok(o) => {
                            secs += o.virtual_seconds;
                            stats.merge(&o.stats);
                        }
                        Err(_) => {
                            oom = true;
                            break;
                        }
                    }
                }
                (secs, stats, oom)
            };
        let looped = |grid: &Grid2D, a: &DistMatrix, b: &DistMatrix, mcfg: &MultiplyConfig| {
            run_iters(&mut || multiply(grid, a, b, mcfg))
        };
        // hot spares park here: world ranks ≥ p never run the compute
        // body — they wait for the session's adoption directive and, if
        // adopted, finish the remaining iterations on the dead seat
        if world.rank() >= p {
            let (rows, cols, layers) = match exec {
                Exec::TwoFive { rows, cols, layers } => (rows, cols, layers),
                _ => unreachable!("spares are asserted onto the steady 2.5D path"),
            };
            let arows = BlockLayout::new(m, spec.block);
            let acols = BlockLayout::new(k, spec.block);
            let brows = BlockLayout::new(k, spec.block);
            let bcols = BlockLayout::new(n, spec.block);
            let mut out = match spare_serve(
                &world,
                (rows, cols, layers),
                &cfg(Algorithm::TwoFiveD { layers }),
                (&arows, &acols),
                (&brows, &bcols),
                spec.mode,
            ) {
                SpareOutcome::Idle => (0.0, MultiplyStats::default(), false, 0.0),
                SpareOutcome::Adopted(seat) => {
                    let mut sess = seat.session;
                    let done = sess.multiplies() as usize;
                    let mut secs = 0.0f64;
                    let mut stats = MultiplyStats::default();
                    let mut oom = false;
                    for _ in done..iters {
                        match sess.multiply_resident(&seat.a, &seat.b) {
                            Ok(o) => {
                                secs += o.virtual_seconds;
                                stats.merge(&o.stats);
                            }
                            Err(_) => {
                                oom = true;
                                break;
                            }
                        }
                    }
                    // the seat's adoption bill is this rank's share of
                    // the recovery ledger
                    stats.recovery_bytes += seat.recovery_bytes;
                    stats.recovery_s += seat.recovery_s;
                    (secs, stats, oom, 0.0)
                }
            };
            let cs = world.stats();
            out.1.retrans_bytes = cs.retrans_bytes;
            out.1.retrans_s = cs.retrans_s;
            return out;
        }
        let (secs, mut stats, oom, repl_s) = match exec {
            // steady state: residency setup once, then `iters` resident
            // multiplies through the session
            Exec::TwoFive { rows, cols, layers } if iters > 1 && spec.spares > 0 => {
                // the compute grid is a strict subview: the trailing
                // spare ranks join the session only through adoption
                let members: Vec<usize> = (0..p).collect();
                let g3 = Grid3D::new(world.subview(&members), rows, cols, layers);
                let coords = g3.grid.coords();
                let (a, b) = operands((rows, cols), coords);
                let mut sess = PipelineSession::new(g3, cfg(Algorithm::TwoFiveD { layers }));
                let (ra, rb) = sess.admit_pair(a, b);
                let repl_s = sess.repl_seconds();
                let mut secs = 0.0f64;
                let mut stats = MultiplyStats::default();
                let mut oom = false;
                // first resident multiply: the injected fault (if any)
                // fires here
                match sess.multiply_resident(&ra, &rb) {
                    Ok(o) => {
                        secs += o.virtual_seconds;
                        stats.merge(&o.stats);
                    }
                    Err(_) => oom = true,
                }
                // splice the spares into any dead seats (or release
                // them); later iterations run full-width
                let report = sess.adopt_spares(&world, &ra, &rb);
                stats.recovery_bytes += report.bytes;
                stats.recovery_s += report.seconds;
                if !world.killed() && !oom {
                    for _ in 1..iters {
                        match sess.multiply_resident(&ra, &rb) {
                            Ok(o) => {
                                secs += o.virtual_seconds;
                                stats.merge(&o.stats);
                            }
                            Err(_) => {
                                oom = true;
                                break;
                            }
                        }
                    }
                }
                stats.repl_bytes = sess.stats().repl_bytes;
                stats.repl_s = sess.stats().repl_s;
                (secs, stats, oom, repl_s)
            }
            Exec::TwoFive { rows, cols, layers } if iters > 1 => {
                let g3 = Grid3D::new(world, rows, cols, layers);
                let coords = g3.grid.coords();
                let (a, b) = operands((rows, cols), coords);
                let mut sess = PipelineSession::new(g3, cfg(Algorithm::TwoFiveD { layers }));
                let (ra, rb) = sess.admit_pair(a, b);
                // the session's own booking is the single source of
                // truth for the setup span
                let repl_s = sess.repl_seconds();
                let (secs, mut stats, oom) =
                    run_iters(&mut || sess.multiply_resident(&ra, &rb));
                // the session's one-time repl_ bucket, surfaced on the
                // aggregated stats (per-call buckets are all zero)
                stats.repl_bytes = sess.stats().repl_bytes;
                stats.repl_s = sess.stats().repl_s;
                (secs, stats, oom, repl_s)
            }
            Exec::TwoFive { rows, cols, layers } => {
                let g3 = Grid3D::new(world, rows, cols, layers);
                // canonical layer-cyclic shares; `Fill::Random` is
                // seeded per global block, so every layer constructs the
                // same share and the replication bcast (still charged to
                // the clocks/counters) re-delivers identical data
                let (mut a, mut b) = operands((rows, cols), g3.grid.coords());
                let t0 = g3.world.now();
                let b0 = g3.world.stats().bytes_sent;
                replicate_to_layers(&g3, &mut a, spec.transport);
                replicate_to_layers(&g3, &mut b, spec.transport);
                let repl_s = g3.world.now() - t0;
                let repl_bytes = g3.world.stats().bytes_sent - b0;
                // span bounds equal the booked delta exactly, so the
                // driver lane reconciles with the `repl_` bucket
                g3.world.prof_span(
                    Lane::Driver,
                    Phase::Replicate,
                    None,
                    t0,
                    g3.world.now(),
                    repl_bytes,
                    None,
                );
                let (gr, gc) = grid_shape(rows * cols * layers);
                let grid = Grid2D::new(g3.world.clone(), gr, gc);
                match multiply(&grid, &a, &b, &cfg(Algorithm::TwoFiveD { layers })) {
                    Ok(o) => {
                        let mut stats = o.stats;
                        stats.repl_bytes = repl_bytes;
                        stats.repl_s = repl_s;
                        (o.virtual_seconds, stats, false, repl_s)
                    }
                    Err(_) => (0.0, MultiplyStats::default(), true, repl_s),
                }
            }
            Exec::Cannon => {
                let grid = Grid2D::new(world, pr, pc);
                let (a, b) = operands((pr, pc), grid.coords());
                let (secs, stats, oom) = looped(&grid, &a, &b, &cfg(Algorithm::Cannon));
                (secs, stats, oom, 0.0)
            }
            Exec::Layout => {
                if is_rect && spec.engine != Engine::Pdgemm {
                    assert!(
                        spec.occupancy >= 1.0,
                        "tall-skinny runs are dense-only; occupancy applies to the \
                         Cannon/2.5D family"
                    );
                    // tall-skinny operand layout (K 1-D over all ranks)
                    let (a, b) =
                        tall_skinny::ts_operands(m, n, k, spec.block, &world, spec.mode, 101, 102);
                    let grid = Grid2D::new(world, 1, p);
                    let (secs, stats, oom) = looped(&grid, &a, &b, &cfg(Algorithm::TallSkinny));
                    (secs, stats, oom, 0.0)
                } else {
                    let grid = Grid2D::new(world, pr, pc);
                    let (a, b) = operands((pr, pc), grid.coords());
                    if spec.engine == Engine::Pdgemm {
                        assert!(
                            spec.occupancy >= 1.0,
                            "the PDGEMM baseline is dense-only; occupancy applies to \
                             the Cannon/2.5D family"
                        );
                        let mcfg = cfg(Algorithm::Cannon);
                        let (secs, stats, oom) =
                            run_iters(&mut || pdgemm(&grid, &a, &b, &mcfg));
                        (secs, stats, oom, 0.0)
                    } else {
                        let (secs, stats, oom) = looped(&grid, &a, &b, &cfg(Algorithm::Cannon));
                        (secs, stats, oom, 0.0)
                    }
                }
            }
        };
        // the run-level reliability ledger: cumulative rank counters, a
        // superset of the per-call windows (replication and adoption
        // phases retransmit too)
        let cs = wstats.stats();
        stats.retrans_bytes = cs.retrans_bytes;
        stats.retrans_s = cs.retrans_s;
        (secs, stats, oom, repl_s)
    });

    let mut stats = MultiplyStats::default();
    let mut seconds = 0.0f64;
    let mut repl_seconds = 0.0f64;
    let mut total_seconds = 0.0f64;
    let mut oom = false;
    for (t, s, rank_oom, repl) in per_rank {
        seconds = seconds.max(t);
        repl_seconds = repl_seconds.max(repl);
        total_seconds = total_seconds.max(repl + t);
        stats.merge(&s);
        oom |= rank_oom;
    }
    let plan = chosen_plan.or_else(|| stats.plan.clone());
    (
        RunResult {
            seconds: if oom { -1.0 } else { seconds },
            repl_seconds,
            total_seconds: if oom { -1.0 } else { total_seconds },
            iterations: iters,
            wall: wall0.elapsed().as_secs_f64(),
            occupancy_a: stats.occupancy_a(),
            occupancy_b: stats.occupancy_b(),
            occupancy_c: stats.occupancy_c(),
            recovery_seconds: stats.recovery_s,
            recovery_bytes: stats.recovery_bytes,
            retrans_bytes: stats.retrans_bytes,
            retrans_seconds: stats.retrans_s,
            overlap_hidden_seconds: stats.overlap_hidden_s,
            meta_bytes: stats.meta_bytes,
            stats,
            plan,
            oom,
            unrecoverable: false,
        },
        trace,
        prof,
    )
}

fn fill_for(mode: Mode, seed: u64) -> Fill {
    match mode {
        Mode::Real => Fill::Random { seed },
        Mode::Model => Fill::Zero,
    }
}

/// Overload for tall-skinny operand construction: (m, k) with N = m.
pub mod tshelp {
    use super::*;
    use crate::dist::CommView;

    pub fn operands(
        m: usize,
        k: usize,
        block: usize,
        world: &CommView,
        mode: Mode,
        sa: u64,
        sb: u64,
    ) -> (DistMatrix, DistMatrix) {
        tall_skinny::ts_operands(m, m, k, block, world, mode, sa, sb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_spec() -> RunSpec {
        RunSpec {
            nodes: 1,
            rpn: 4,
            threads: 3,
            block: 22,
            shape: Shape::Square { n: 1408 },
            engine: Engine::DbcsrDensified,
            mode: Mode::Model,
            net: NetModel::aries(4),
            transport: Transport::TwoSided,
            overlap: false,
            algo: AlgoSpec::Layout,
            plan_verbose: false,
            occupancy: 1.0,
            iterations: 1,
            fault: None,
            faultnet: None,
            fault_policy: FaultPolicy::Retry,
            spares: 0,
        }
    }

    #[test]
    fn grid_shape_most_square() {
        assert_eq!(grid_shape(16), (4, 4));
        assert_eq!(grid_shape(24), (4, 6));
        assert_eq!(grid_shape(192), (12, 16));
        assert_eq!(grid_shape(1), (1, 1));
        assert_eq!(grid_shape(7), (1, 7));
    }

    #[test]
    fn shapes() {
        assert_eq!(Shape::paper_square().dims(), (63_360, 63_360, 63_360));
        let (m, n, k) = Shape::paper_rect().dims();
        assert_eq!((m, n), (1_408, 1_408));
        assert_eq!(k, 1_982_464);
        assert_eq!(Shape::Square { n: 64 }.scaled(2).dims().0, 32);
    }

    #[test]
    fn model_point_square_densified() {
        let r = run_spec(RunSpec {
            shape: Shape::Square { n: 2816 },
            ..base_spec()
        });
        assert!(!r.oom);
        assert!(r.seconds > 0.0);
        assert!(r.stats.flops > 0);
        // layout points don't replicate, and multiply reports its plan
        assert_eq!(r.repl_seconds, 0.0);
        assert_eq!(r.total_seconds, r.seconds);
        assert_eq!(r.plan.as_ref().unwrap().algorithm, "cannon");
    }

    #[test]
    fn model_point_rect_ts() {
        let r = run_spec(RunSpec {
            shape: Shape::Rect { mn: 352, k: 22528 },
            ..base_spec()
        });
        assert!(!r.oom && r.seconds > 0.0);
        assert_eq!(r.plan.as_ref().unwrap().algorithm, "tall-skinny");
    }

    #[test]
    fn model_point_pdgemm() {
        let r = run_spec(RunSpec {
            shape: Shape::Square { n: 2816 },
            engine: Engine::Pdgemm,
            ..base_spec()
        });
        assert!(!r.oom && r.seconds > 0.0);
    }

    #[test]
    fn net_model_comes_from_the_spec() {
        // regression: the harness used to hardcode NetModel::aries(rpn);
        // an ideal-fabric sweep must show zero comm wait and run faster
        let point = |net: NetModel| {
            run_spec(RunSpec {
                net,
                ..base_spec()
            })
        };
        let aries = point(NetModel::aries(4));
        let ideal = point(NetModel::ideal());
        assert!(ideal.stats.comm_wait_s == 0.0, "{}", ideal.stats.comm_wait_s);
        assert!(aries.stats.comm_wait_s > 0.0);
        assert!(ideal.seconds < aries.seconds);
        assert_eq!(ideal.stats.comm_bytes, aries.stats.comm_bytes);
    }

    #[test]
    fn one_sided_transport_sweeps_through_the_harness() {
        let point = |transport: Transport| {
            run_spec(RunSpec {
                nodes: 4,
                transport,
                ..base_spec()
            })
        };
        let two = point(Transport::TwoSided);
        let one = point(Transport::OneSided);
        assert_eq!(two.stats.comm_bytes, one.stats.comm_bytes);
        assert!(
            one.stats.comm_wait_s < two.stats.comm_wait_s,
            "one-sided must lower comm wait ({} vs {})",
            one.stats.comm_wait_s,
            two.stats.comm_wait_s
        );
    }

    #[test]
    fn real_point_matches_model_counters() {
        let spec = |mode| RunSpec {
            threads: 2,
            block: 8,
            shape: Shape::Square { n: 64 },
            engine: Engine::DbcsrBlocked,
            mode,
            ..base_spec()
        };
        let r = run_spec(spec(Mode::Real));
        let m = run_spec(spec(Mode::Model));
        assert_eq!(r.stats.block_mults, m.stats.block_mults);
        assert_eq!(r.stats.stacks, m.stats.stacks);
    }

    #[test]
    fn fixed_c_point_replicates_and_reports() {
        let r = run_spec(RunSpec {
            nodes: 4,
            algo: AlgoSpec::TwoFiveD { layers: 2 },
            ..base_spec()
        });
        assert!(!r.oom && r.seconds > 0.0);
        assert!(r.repl_seconds > 0.0, "in-run replication must be charged");
        assert!(r.stats.repl_bytes > 0, "repl_ bucket must carry the bcast");
        // per-rank sums: between the phase maxima and their sum
        assert!(r.total_seconds >= r.seconds && r.total_seconds >= r.repl_seconds);
        assert!(r.total_seconds <= r.seconds + r.repl_seconds + 1e-12);
        let plan = r.plan.as_ref().unwrap();
        assert_eq!((plan.algorithm.as_str(), plan.layers), ("2.5d", 2));
        assert_eq!(plan.source, "explicit");
    }

    #[test]
    fn steady_point_amortizes_setup_across_iterations() {
        // N resident iterations must cost one setup + N × per-iteration
        // (per-call phases only), not N × (setup + per-call)
        let point = |iterations: usize| {
            run_spec(RunSpec {
                nodes: 4,
                algo: AlgoSpec::TwoFiveD { layers: 4 },
                iterations,
                ..base_spec()
            })
        };
        let one = point(1);
        let four = point(4);
        assert!(!one.oom && !four.oom);
        assert_eq!(four.iterations, 4);
        // setup charged once: repl cost does not scale with iterations
        // (the steady setup adds the pre-skew on top of the one-shot
        // bcast, but can never approach 4 setups)
        assert!(four.repl_seconds < 3.0 * one.repl_seconds + 1e-12);
        assert!(four.stats.repl_bytes < 2 * one.stats.repl_bytes.max(1));
        // and the amortized total beats per-call repetition
        assert!(
            four.total_seconds < 4.0 * one.total_seconds,
            "steady {} vs per-call {}",
            four.total_seconds,
            4.0 * one.total_seconds
        );
    }

    #[test]
    fn steady_iterations_scale_multiply_time_linearly() {
        let point = |iterations: usize| {
            run_spec(RunSpec {
                nodes: 4,
                algo: AlgoSpec::TwoFiveD { layers: 2 },
                iterations,
                ..base_spec()
            })
        };
        let two = point(2);
        let four = point(4);
        let six = point(6);
        // deterministic clocks: once past the first iteration's sync
        // catch-up, every further resident iteration costs exactly the
        // same — consecutive two-iteration increments are identical
        let d1 = four.seconds - two.seconds;
        let d2 = six.seconds - four.seconds;
        assert!(d1 > 0.0 && d2 > 0.0);
        assert!((d1 - d2).abs() <= 1e-9 * d1, "{d1} vs {d2}");
        // comm volume is exactly linear in the iteration count
        assert_eq!(two.stats.comm_bytes * 2, four.stats.comm_bytes);
        assert_eq!(two.stats.comm_bytes * 3, six.stats.comm_bytes);
    }

    #[test]
    fn steady_auto_runs_the_planned_session() {
        let auto = run_spec(RunSpec {
            nodes: 4,
            algo: AlgoSpec::Auto,
            iterations: 8,
            ..base_spec()
        });
        let plan = auto.plan.clone().expect("auto must surface a plan");
        assert_eq!(plan.source, "model");
        assert_eq!(plan.horizon, 8);
        assert!(plan.charged_replication);
        // bit-identical to the fixed resident point at the chosen c
        let fixed = run_spec(RunSpec {
            nodes: 4,
            algo: AlgoSpec::TwoFiveD {
                layers: plan.layers,
            },
            iterations: 8,
            ..base_spec()
        });
        assert_eq!(auto.seconds, fixed.seconds);
        assert_eq!(auto.total_seconds, fixed.total_seconds);
        assert_eq!(auto.stats.comm_bytes, fixed.stats.comm_bytes);
    }

    #[test]
    fn sparse_points_report_occupancy_and_cut_comm() {
        let point = |occupancy: f64| {
            run_spec(RunSpec {
                nodes: 4,
                // blocked engine: block_mults counts symbolic triples,
                // which is what occupancy must scale (the densified
                // engine counts per-thread GEMMs regardless of fill)
                engine: Engine::DbcsrBlocked,
                occupancy,
                ..base_spec()
            })
        };
        let dense = point(1.0);
        let sparse = point(0.1);
        assert!(!sparse.oom && sparse.seconds > 0.0);
        // achieved occupancy tracks the requested one (deterministic
        // predicate, wide tolerance for the finite pattern)
        assert!(dense.occupancy_a == 1.0 && dense.occupancy_b == 1.0);
        assert!(
            (0.05..0.2).contains(&sparse.occupancy_a),
            "{}",
            sparse.occupancy_a
        );
        // occupancy-proportional wire format: sparse ships far fewer
        // bytes, and its metadata share is nonzero
        assert!(sparse.stats.comm_bytes < dense.stats.comm_bytes / 4);
        assert!(sparse.stats.meta_bytes > 0);
        assert!(sparse.stats.meta_bytes <= sparse.stats.comm_bytes);
        // modeled compute scales too (block_mults ∝ occ_a·occ_b)
        assert!(sparse.stats.block_mults < dense.stats.block_mults / 10);
    }

    #[test]
    fn sparse_auto_plans_with_occupancy() {
        let r = run_spec(RunSpec {
            nodes: 4,
            algo: AlgoSpec::Auto,
            occupancy: 0.01,
            ..base_spec()
        });
        assert!(!r.oom);
        let plan = r.plan.expect("auto surfaces a plan");
        assert_eq!(plan.source, "model");
        assert!(plan.predicted_seconds > 0.0);
    }

    #[test]
    fn fixed_c1_degenerates_to_cannon() {
        let point = |algo: AlgoSpec| {
            run_spec(RunSpec {
                nodes: 4,
                algo,
                ..base_spec()
            })
        };
        let cannon = point(AlgoSpec::Cannon);
        let c1 = point(AlgoSpec::TwoFiveD { layers: 1 });
        assert_eq!(cannon.stats.comm_bytes, c1.stats.comm_bytes);
        assert_eq!(cannon.seconds, c1.seconds);
        assert_eq!(c1.repl_seconds, 0.0);
    }

    #[test]
    fn auto_surfaces_a_model_plan_and_matches_its_fixed_point() {
        let auto = run_spec(RunSpec {
            nodes: 4,
            algo: AlgoSpec::Auto,
            ..base_spec()
        });
        let plan = auto.plan.clone().expect("auto must surface a plan");
        assert_eq!(plan.source, "model");
        assert!(plan.predicted_seconds > 0.0);
        // the auto point is bit-identical to the fixed point at the
        // chosen c (same machinery, deterministic clocks)
        let fixed = run_spec(RunSpec {
            nodes: 4,
            algo: AlgoSpec::TwoFiveD { layers: plan.layers },
            ..base_spec()
        });
        assert_eq!(auto.seconds, fixed.seconds);
        assert_eq!(auto.total_seconds, fixed.total_seconds);
        assert_eq!(auto.stats.comm_bytes, fixed.stats.comm_bytes);
    }
}
