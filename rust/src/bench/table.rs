//! Minimal table rendering for bench output (criterion substitute).

use crate::util::json::{obj, Json};

/// A printable results table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("title", self.title.as_str().into()),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| h.as_str().into()).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| c.as_str().into()).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Format seconds for table cells.
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0 {
        "OOM".to_string()
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("long_header"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("x", &["h"]);
        t.row(vec!["v".into()]);
        let j = t.to_json();
        assert_eq!(j.get("rows").idx(0).idx(0).as_str(), Some("v"));
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(-1.0), "OOM");
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-2).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }
}
