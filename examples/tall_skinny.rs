//! Tall-and-skinny multiplication — the paper's rectangular workload
//! (§IV: M = N = 1 408, K = 1 982 464) at reduced scale, comparing the
//! O(1)-communication algorithm against Cannon and PDGEMM on the same
//! operands.
//!
//! Run: `cargo run --release --offline --example tall_skinny [-- --scale 16]`

use dbcsr::bench::harness::{run_spec, AlgoSpec, Engine, RunSpec, Shape};
use dbcsr::bench::table::{fmt_secs, Table};
use dbcsr::config::Args;
use dbcsr::dist::{run_ranks, Grid2D, NetModel, Transport};
use dbcsr::matrix::{DistMatrix, Mode};
use dbcsr::matrix::matrix::Fill;
use dbcsr::multiply::{multiply, tall_skinny, Algorithm, EngineOpts, MultiplyConfig};

fn main() {
    let args = Args::parse(std::env::args());
    let scale = args.usize_flag("scale", 16);
    let shape = Shape::paper_rect().scaled(scale);
    let (m, _, k) = shape.dims();
    println!("tall-and-skinny workload: M = N = {m}, K = {k} (paper / {scale})\n");

    // --- communication scaling: TS is O(1) in K and P ---------------------
    let mut t = Table::new(
        "per-rank communication, tall-skinny vs Cannon (block 22, model)",
        &["ranks", "TS bytes/rank", "Cannon bytes/rank", "TS advantage"],
    );
    for p in [4usize, 16] {
        let ts_bytes = comm_bytes(p, m, k, Algorithm::TallSkinny);
        let cn_bytes = comm_bytes(p, m, k, Algorithm::Cannon);
        t.row(vec![
            p.to_string(),
            format!("{:.2} MiB", ts_bytes / (1 << 20) as f64),
            format!("{:.2} MiB", cn_bytes / (1 << 20) as f64),
            format!("{:.1}x", cn_bytes / ts_bytes),
        ]);
    }
    t.print();

    // --- end-to-end timing vs PDGEMM (miniature Fig. 4b) ------------------
    let mut t = Table::new(
        "virtual time on 4 nodes (4 x 3), block 22",
        &["engine", "virtual time"],
    );
    for (name, engine) in [
        ("DBCSR tall-skinny densified", Engine::DbcsrDensified),
        ("DBCSR tall-skinny blocked", Engine::DbcsrBlocked),
        ("PDGEMM (SUMMA baseline)", Engine::Pdgemm),
    ] {
        let r = run_spec(RunSpec {
            nodes: 4,
            rpn: 4,
            threads: 3,
            block: 22,
            shape,
            engine,
            mode: Mode::Model,
            net: NetModel::aries(4),
            transport: Transport::TwoSided,
            algo: AlgoSpec::Layout,
            plan_verbose: false,
            occupancy: 1.0,
            iterations: 1,
        });
        t.row(vec![name.to_string(), fmt_secs(r.seconds)]);
    }
    t.print();
    println!("(full-scale series: `dbcsr fig4`, see EXPERIMENTS.md E5)");
}

/// Total per-rank comm bytes for the rect workload under an algorithm.
fn comm_bytes(p: usize, m: usize, k: usize, algorithm: Algorithm) -> f64 {
    let parts = run_ranks(p, NetModel::aries(4), move |world| {
        let cfg = MultiplyConfig {
            engine: EngineOpts {
                threads: 3,
                densify: true,
                ..Default::default()
            },
            algorithm,
            gpu_share: 4,
            runtime: None,
            ..Default::default()
        };
        let out = match algorithm {
            Algorithm::TallSkinny => {
                let (a, b) = tall_skinny::ts_operands(m, m, k, 22, &world, Mode::Model, 1, 2);
                let grid = Grid2D::new(world, 1, p);
                multiply(&grid, &a, &b, &cfg).unwrap()
            }
            _ => {
                let (pr, pc) = dbcsr::bench::harness::grid_shape(p);
                let grid = Grid2D::new(world, pr, pc);
                let coords = grid.coords();
                let a = DistMatrix::dense_cyclic(m, k, 22, (pr, pc), coords, Mode::Model, Fill::Zero);
                let b = DistMatrix::dense_cyclic(k, m, 22, (pr, pc), coords, Mode::Model, Fill::Zero);
                multiply(&grid, &a, &b, &cfg).unwrap()
            }
        };
        out.stats.comm_bytes
    });
    parts.iter().sum::<u64>() as f64 / p as f64
}
