//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! Run: `make artifacts && cargo run --release --offline --example e2e_dense`
//!
//! Proves all layers compose (DESIGN.md §7):
//!   L1/L2 — the AOT Pallas GEMM/SMM artifacts are loaded from
//!           `artifacts/` and executed through PJRT (the cuBLAS /
//!           LIBCUSMM analogs); Python is never invoked;
//!   L3   — 4 rank-threads form a 2×2 grid; real block-cyclic matrices
//!           are multiplied with **blocked DBCSR**, **densified DBCSR**
//!           (§III) and the **PDGEMM baseline** on the same inputs;
//! every result is verified against a dense reference, and the headline
//! metric (densified-DBCSR vs PDGEMM, plus blocked-vs-densified) is
//! reported in modeled P100 time alongside testbed wallclock.
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::rc::Rc;
use std::time::Instant;

use dbcsr::backend::smm_cpu;
use dbcsr::bench::table::{fmt_secs, Table};
use dbcsr::dist::{run_ranks, Grid2D, NetModel};
use dbcsr::matrix::matrix::{dense_reference, Fill};
use dbcsr::matrix::{BlockLayout, DistMatrix, Distribution, Mode};
use dbcsr::multiply::{multiply, EngineOpts, MultiplyConfig};
use dbcsr::runtime::{artifacts_dir, Runtime};
use dbcsr::scalapack::pdgemm;

const N: usize = 704; // 32 blocks of 22
const BLOCK: usize = 22;

fn main() {
    // verify artifacts exist before spawning ranks
    let dir = artifacts_dir();
    let probe = Runtime::load(&dir).expect("run `make artifacts` first");
    println!(
        "e2e: {} AOT artifacts loaded from {} (PJRT CPU client)",
        probe.manifest.variants.len(),
        dir.display()
    );
    drop(probe);
    println!("workload: C = A·B, {N}x{N}x{N}, block {BLOCK}, 2x2 grid, 3 threads/rank\n");

    let mut table = Table::new(
        "e2e results (real numerics through PJRT artifacts)",
        &["engine", "wallclock", "modeled P100 time", "stacks", "max |err|"],
    );
    let mut modeled = Vec::new();
    for (name, which) in [
        ("DBCSR blocked", 0usize),
        ("DBCSR densified", 1),
        ("PDGEMM baseline", 2),
    ] {
        let wall0 = Instant::now();
        let parts = run_ranks(4, NetModel::aries(4), move |world| {
            // one PJRT runtime per rank (as one cuBLAS context per rank)
            let runtime = Rc::new(Runtime::load(&artifacts_dir()).expect("artifacts"));
            let grid = Grid2D::new(world, 2, 2);
            let coords = grid.coords();
            let mk_mat = |rows, cols, seed| {
                DistMatrix::dense(
                    BlockLayout::new(rows, BLOCK),
                    BlockLayout::new(cols, BLOCK),
                    Distribution::cyclic(2),
                    Distribution::cyclic(2),
                    coords,
                    Mode::Real,
                    Fill::Random { seed },
                )
            };
            let a = mk_mat(N, N, 81);
            let b = mk_mat(N, N, 82);
            let cfg = MultiplyConfig {
                engine: EngineOpts {
                    threads: 3,
                    densify: which == 1,
                    ..Default::default()
                },
                gpu_share: 4,
                runtime: Some(runtime),
                ..Default::default()
            };
            let out = if which == 2 {
                pdgemm(&grid, &a, &b, &cfg).unwrap()
            } else {
                multiply(&grid, &a, &b, &cfg).unwrap()
            };
            let mut dense = vec![0.0f32; N * N];
            out.c.add_into_dense(&mut dense);
            (dense, out.virtual_seconds, out.stats.stacks)
        });
        let wall = wall0.elapsed().as_secs_f64();

        // gather + verify
        let mut got = vec![0.0f32; N * N];
        let mut vt = 0.0f64;
        let mut stacks = 0u64;
        for (part, t, s) in &parts {
            for (g, x) in got.iter_mut().zip(part.iter()) {
                *g += x;
            }
            vt = vt.max(*t);
            stacks += s;
        }
        let layout = BlockLayout::new(N, BLOCK);
        let ar = dense_reference(&layout, &layout, 81);
        let br = dense_reference(&layout, &layout, 82);
        let mut want = vec![0.0f32; N * N];
        smm_cpu::gemm_blocked(N, N, N, &ar, &br, &mut want);
        let max_err = got
            .iter()
            .zip(want.iter())
            .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-2, "{name}: verification failed ({max_err})");

        modeled.push(vt);
        table.row(vec![
            name.to_string(),
            format!("{wall:.2}s"),
            fmt_secs(vt),
            stacks.to_string(),
            format!("{max_err:.1e}"),
        ]);
    }
    table.print();

    println!("headline (modeled P100 node, this workload):");
    println!(
        "  densified DBCSR vs PDGEMM:  {:.2}x",
        modeled[2] / modeled[1]
    );
    println!(
        "  densified vs blocked DBCSR: {:.2}x",
        modeled[0] / modeled[1]
    );
    println!("  (paper at full scale: 1.1-2.5x and up to 1.8x — see EXPERIMENTS.md)");
    println!("e2e OK — all three engines verified against the dense reference");
}
