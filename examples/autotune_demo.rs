//! The LIBCUSMM-analog autotuning workflow (§II): enumerate the kernel
//! parameter space per block size, measure a training subset, fit the
//! regression-tree performance model, and dispatch the predicted winners.
//!
//! Run: `cargo run --release --offline --example autotune_demo`

use dbcsr::backend::autotune::{features, measure, param_space, Autotuner, RegressionTree};
use dbcsr::bench::table::Table;
use dbcsr::perfmodel::PerfModel;

fn main() {
    let perf = PerfModel::default();

    // 1. the parameter space (the paper's ~30k-150k combos per (m,n,k);
    //    our TPU-rethought space is smaller but same structure)
    let space22 = param_space(22, 22, 22);
    println!(
        "parameter space for 22x22x22: {} candidates (grouping x unroll x padding)\n",
        space22.len()
    );

    // 2. exhaustive measurement on training sizes
    let mut tuner = Autotuner::new(perf.clone());
    let train: Vec<(usize, usize, usize)> =
        [4usize, 8, 16, 32, 48, 80].iter().map(|&s| (s, s, s)).collect();
    tuner.fit(&train);
    println!("fitted regression tree on {} training sizes", train.len());

    // 3. model quality: predicted winners vs exhaustive winners on
    //    held-out sizes (the paper's sizes 22 and 64 are NOT in training)
    let mut t = Table::new(
        "predicted vs exhaustive winners (held-out block sizes)",
        &["size", "predicted params", "achieved GF/s", "best GF/s", "quality"],
    );
    for &s in &[22usize, 64] {
        let predicted = tuner.tune_predicted(s, s, s);
        let truth = tuner.tune_exhaustive(s, s, s);
        let achieved = measure(&perf, s, s, s, &predicted.params);
        t.row(vec![
            format!("{s}"),
            format!(
                "g={} unroll={} pad={}",
                predicted.params.grouping, predicted.params.unroll, predicted.params.pad_m
            ),
            format!("{achieved:.0}"),
            format!("{:.0}", truth.gflops),
            format!("{:.0}%", 100.0 * achieved / truth.gflops),
        ]);
    }
    t.print();

    // 4. the full tuned table (what aot.py bakes into the artifacts)
    let sizes: Vec<(usize, usize, usize)> =
        [4usize, 8, 16, 22, 32, 48, 64, 80].iter().map(|&s| (s, s, s)).collect();
    let tuned = tuner.tune(&sizes, 2);
    let mut t = Table::new(
        "tuned SMM kernel table (→ python/compile/aot.py SMM_PARAMS)",
        &["size", "grouping", "unroll", "est GF/s", "source"],
    );
    for tu in &tuned {
        t.row(vec![
            tu.m.to_string(),
            tu.params.grouping.to_string(),
            tu.params.unroll.to_string(),
            format!("{:.0}", tu.gflops),
            if tu.measured { "measured" } else { "model" }.to_string(),
        ]);
    }
    t.print();

    // 5. peek inside the tree
    let model: &RegressionTree = tuner.model.as_ref().unwrap();
    println!(
        "regression tree: {} nodes, depth {}; example features for (22³, winner): {:?}",
        model.node_count(),
        model.depth(),
        features(22, 22, 22, &tuned[3].params).0
    );
}
