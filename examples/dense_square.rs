//! Dense square multiplication — the paper's §IV-A/§IV-B workload at
//! reduced scale, sweeping grid configurations and both engine paths.
//!
//! Run: `cargo run --release --offline --example dense_square [-- --scale 40]`
//!
//! Model mode at a scaled-down version of the paper's square workload
//! (M = N = K = 63 360 / scale, blocks 22 and 64): regenerates miniature
//! Fig. 2 (grid configs) and Fig. 3(a) (blocked vs densified) rows on one
//! node's worth of ranks, printing virtual times from the P100/Aries
//! model.

use dbcsr::bench::harness::{run_spec, AlgoSpec, Engine, RunSpec, Shape};
use dbcsr::bench::table::{fmt_secs, Table};
use dbcsr::config::Args;
use dbcsr::dist::{NetModel, Transport};
use dbcsr::matrix::Mode;

fn main() {
    let args = Args::parse(std::env::args());
    let scale = args.usize_flag("scale", 40);
    let shape = Shape::paper_square().scaled(scale);
    let (m, _, _) = shape.dims();
    println!("dense square workload: M = N = K = {m} (paper / {scale})\n");

    // miniature Fig. 2: grid configurations on 4 nodes
    let mut t = Table::new(
        "grid configurations (densified, block 22, 4 nodes)",
        &["ranks x threads", "virtual time", "stacks", "GPU peak GiB"],
    );
    for (rpn, threads) in [(4, 3), (1, 12), (12, 1), (6, 2)] {
        let r = run_spec(RunSpec {
            nodes: 4,
            rpn,
            threads,
            block: 22,
            shape,
            engine: Engine::DbcsrDensified,
            mode: Mode::Model,
            net: NetModel::aries(rpn),
            transport: Transport::TwoSided,
            algo: AlgoSpec::Layout,
            plan_verbose: false,
            occupancy: 1.0,
            iterations: 1,
        });
        t.row(vec![
            format!("{rpn} x {threads}"),
            fmt_secs(r.seconds),
            r.stats.stacks.to_string(),
            format!("{:.2}", r.stats.dev_mem_peak as f64 / (1 << 30) as f64),
        ]);
    }
    t.print();

    // miniature Fig. 3(a): blocked vs densified per block size
    let mut t = Table::new(
        "blocked vs densified (4 x 3 on 4 nodes)",
        &["block", "blocked", "densified", "ratio"],
    );
    for block in [22usize, 64] {
        let mut pair = Vec::new();
        for engine in [Engine::DbcsrBlocked, Engine::DbcsrDensified] {
            let r = run_spec(RunSpec {
                nodes: 4,
                rpn: 4,
                threads: 3,
                block,
                shape,
                engine,
                mode: Mode::Model,
                net: NetModel::aries(4),
                transport: Transport::TwoSided,
                algo: AlgoSpec::Layout,
                plan_verbose: false,
                occupancy: 1.0,
                iterations: 1,
            });
            pair.push(r.seconds);
        }
        t.row(vec![
            block.to_string(),
            fmt_secs(pair[0]),
            fmt_secs(pair[1]),
            format!("{:.2}x", pair[0] / pair[1]),
        ]);
    }
    t.print();
    println!("(full-scale figures: `dbcsr fig2` / `dbcsr fig3`, see EXPERIMENTS.md)");
}
