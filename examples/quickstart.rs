//! Quickstart: create a distributed blocked matrix, multiply it, verify.
//!
//! Run: `cargo run --release --offline --example quickstart`
//!
//! Four threads-as-ranks form a 2×2 grid; two 128×128 matrices (block 22,
//! block-cyclic à la ScaLAPACK) are multiplied with Cannon + densification
//! (§III), verified against a dense reference, and the library's matrix
//! API (trace, Frobenius norm, transpose) is exercised.

use dbcsr::backend::smm_cpu;
use dbcsr::dist::{run_ranks, Grid2D, NetModel};
use dbcsr::matrix::matrix::{dense_reference, Fill};
use dbcsr::matrix::ops::transpose;
use dbcsr::matrix::{BlockLayout, DistMatrix, Distribution, Mode};
use dbcsr::multiply::{multiply, MultiplyConfig};

const N: usize = 128;
const BLOCK: usize = 22;

fn main() {
    // 4 ranks (threads) on 2 nodes of the modeled network
    let results = run_ranks(4, NetModel::aries(2), |world| {
        let grid = Grid2D::new(world, 2, 2);
        let coords = grid.coords();

        // block-cyclic distributed dense matrices with deterministic fill
        let a = DistMatrix::dense(
            BlockLayout::new(N, BLOCK),
            BlockLayout::new(N, BLOCK),
            Distribution::cyclic(2),
            Distribution::cyclic(2),
            coords,
            Mode::Real,
            Fill::Random { seed: 1 },
        );
        let b = DistMatrix::dense(
            BlockLayout::new(N, BLOCK),
            BlockLayout::new(N, BLOCK),
            Distribution::cyclic(2),
            Distribution::cyclic(2),
            coords,
            Mode::Real,
            Fill::Random { seed: 2 },
        );

        // single-matrix API
        let tr = a.trace(&grid.world);
        let fro = a.frobenius_sq(&grid.world);
        let _at = transpose(&a, &grid.world, (2, 2));

        // C = A · B (Cannon + densification by default)
        let cfg = MultiplyConfig::default();
        let out = multiply(&grid, &a, &b, &cfg).expect("multiply");

        let mut dense = vec![0.0f32; N * N];
        out.c.add_into_dense(&mut dense);
        (dense, tr, fro, out.virtual_seconds, out.stats)
    });

    // verify against the dense reference on the driver thread
    let mut got = vec![0.0f32; N * N];
    for (part, ..) in &results {
        for (g, x) in got.iter_mut().zip(part.iter()) {
            *g += x;
        }
    }
    let layout = BlockLayout::new(N, BLOCK);
    let ar = dense_reference(&layout, &layout, 1);
    let br = dense_reference(&layout, &layout, 2);
    let mut want = vec![0.0f32; N * N];
    smm_cpu::gemm_blocked(N, N, N, &ar, &br, &mut want);
    let max_err = got
        .iter()
        .zip(want.iter())
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);

    let (_, tr, fro, vt, stats) = &results[0];
    println!("quickstart: C = A·B on a 2x2 grid, {N}x{N}, block {BLOCK}");
    println!("  trace(A)      = {tr:.4}");
    println!("  ||A||_F^2     = {fro:.2}");
    println!("  virtual time  = {:.2} ms (modeled P100 node)", vt * 1e3);
    println!(
        "  stats: {} stacks, {} block mults, {:.1} KiB comm",
        stats.stacks,
        stats.block_mults,
        stats.comm_bytes as f64 / 1024.0
    );
    println!("  max |C - C_ref| = {max_err:.2e}");
    assert!(max_err < 1e-2, "verification failed");
    println!("OK");
}
