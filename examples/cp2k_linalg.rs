//! CP2K-style consumer workload: the linear-algebra methods DBCSR hosts
//! for its main client (§II / ref [1] — linear-scaling SCF): matrix sign,
//! inverse, exponential and an Arnoldi extremal-eigenvalue estimate, all
//! running on top of the distributed multiplication pipeline — plus the
//! steady-state variant, where the Newton iterations run through a 2.5D
//! `PipelineSession` and the operand replication is paid once instead of
//! per multiply.
//!
//! Run: `cargo run --release --offline --example cp2k_linalg`

use dbcsr::dist::{run_ranks, Grid2D, Grid3D, NetModel};
use dbcsr::linalg;
use dbcsr::matrix::matrix::Fill;
use dbcsr::matrix::{BlockLayout, DistMatrix, Distribution, Mode};
use dbcsr::multiply::{multiply, MultiplyConfig, PipelineSession};

const N: usize = 88; // 4 blocks of 22
const BLOCK: usize = 22;

fn main() {
    let results = run_ranks(4, NetModel::aries(2), |world| {
        let grid = Grid2D::new(world, 2, 2);
        let coords = grid.coords();

        // a well-conditioned symmetric-ish "Hamiltonian": I + 0.05 R
        let mut h = DistMatrix::dense(
            BlockLayout::new(N, BLOCK),
            BlockLayout::new(N, BLOCK),
            Distribution::cyclic(2),
            Distribution::cyclic(2),
            coords,
            Mode::Real,
            Fill::Random { seed: 2024 },
        );
        h.scale(0.05);
        let id = linalg::identity_like(&h);
        h.add_scaled(&id, 1.0);

        let cfg = MultiplyConfig::default();

        // spectral probe (Arnoldi/power) — CP2K uses this to scale
        // Newton–Schulz iterations
        let (lambda, resid) = linalg::arnoldi_extremal_eigs(&h, &grid.world, 40, 7);

        // sign(H) for a positive-definite H is the identity
        let (sign, sign_iters) = linalg::matrix_sign(&grid, &h, &cfg, 30, 1e-4).unwrap();
        let mut sign_err = sign.clone();
        sign_err.add_scaled(&id, -1.0);
        let sign_dev = sign_err.frobenius_sq(&grid.world).sqrt();

        // H⁻¹ by Newton–Hotelling, validated by H·H⁻¹ ≈ I
        let (hinv, inv_iters) = linalg::matrix_inverse(&grid, &h, &cfg, 60, 1e-4).unwrap();
        let prod = multiply(&grid, &h, &hinv, &cfg).unwrap().c;
        let mut inv_err = prod;
        inv_err.add_scaled(&id, -1.0);
        let inv_dev = inv_err.frobenius_sq(&grid.world).sqrt();

        // exp(-H) (imaginary-time propagator flavor)
        let mut mh = h.clone();
        mh.scale(-1.0);
        let expm = linalg::matrix_exp(&grid, &mh, &cfg, 10).unwrap();
        let exp_trace = expm.trace(&grid.world);

        (lambda, resid, sign_iters, sign_dev, inv_iters, inv_dev, exp_trace)
    });

    let (lambda, resid, sign_iters, sign_dev, inv_iters, inv_dev, exp_trace) = results[0];
    println!("cp2k-style linear algebra on DBCSR multiply ({N}x{N}, block {BLOCK}, 2x2 grid)");
    println!("  Arnoldi λ_max ≈ {lambda:.4} (residual {resid:.2e})");
    println!("  sign(H):  converged in {sign_iters} Newton–Schulz iters, ‖sign−I‖ = {sign_dev:.2e}");
    println!("  H⁻¹:      converged in {inv_iters} Newton–Hotelling iters, ‖H·H⁻¹−I‖ = {inv_dev:.2e}");
    println!("  tr exp(−H) = {exp_trace:.4}  (n·e⁻¹ ≈ {:.4} for H ≈ I)", N as f32 * (-1.0f32).exp());
    assert!(sign_dev < 1e-2 && inv_dev < 1e-2);

    // the same Newton–Hotelling inverse, steady state: 8 ranks as a
    // 2x2x2 topology, H admitted layer-resident once, every iteration's
    // multiplies skip replication and skew (only the one-time admits
    // land in the session's repl_ bucket)
    let steady = run_ranks(8, NetModel::aries(2), |world| {
        let g3 = Grid3D::new(world, 2, 2, 2);
        let coords = g3.grid.coords();
        let mut h = DistMatrix::dense(
            BlockLayout::new(N, BLOCK),
            BlockLayout::new(N, BLOCK),
            Distribution::cyclic(2),
            Distribution::cyclic(2),
            coords,
            Mode::Real,
            Fill::Random { seed: 2024 },
        );
        h.scale(0.05);
        let id = linalg::identity_like(&h);
        h.add_scaled(&id, 1.0);
        let mut sess = PipelineSession::new(g3, MultiplyConfig::default());
        let (hinv, iters) = linalg::matrix_inverse_resident(&mut sess, &h, 60, 1e-4).unwrap();
        // validate on the resident handles: H·H⁻¹ reduced onto layer 0
        let ra = sess.admit(h, dbcsr::multiply::Sides::A);
        let prod = sess.multiply_resident(&ra, &hinv).unwrap();
        let mut dense = vec![0.0f32; N * N];
        prod.c.add_into_dense(&mut dense);
        (iters, dense, sess.repl_bytes(), sess.stats().comm_bytes)
    });
    let mut got = vec![0.0f32; N * N];
    for (_, dense, _, _) in &steady {
        for (g, x) in got.iter_mut().zip(dense.iter()) {
            *g += x;
        }
    }
    let mut dev = 0.0f64;
    for i in 0..N {
        for j in 0..N {
            let want = if i == j { 1.0 } else { 0.0 };
            dev += (got[i * N + j] as f64 - want).powi(2);
        }
    }
    let residency: u64 = steady.iter().map(|(_, _, b, _)| *b).sum();
    let per_call: u64 = steady.iter().map(|(_, _, _, b)| *b).sum();
    println!(
        "  steady H⁻¹ (2x2x2 session): {} iters, ‖H·H⁻¹−I‖ = {:.2e}; residency \
         traffic (one admit of H + per-step product re-admissions) {:.1} KiB vs \
         {:.1} KiB of resident multiply traffic",
        steady[0].0,
        dev.sqrt(),
        residency as f64 / 1024.0,
        per_call as f64 / 1024.0,
    );
    assert!(dev.sqrt() < 1e-2);
    println!("OK");
}
