//! The 2.5D communication-avoiding multiply (arXiv:1705.10218) end to
//! end: real-mode numerics on a 2×2×2 process grid checked against the
//! dense reference, then a model-mode comm-volume comparison with Cannon.
//!
//! Run: `cargo run --release --offline --example twofive_demo`

use dbcsr::backend::smm_cpu;
use dbcsr::bench::table::Table;
use dbcsr::dist::{run_ranks, Grid2D, Grid3D, NetModel};
use dbcsr::matrix::matrix::{dense_reference, Fill};
use dbcsr::matrix::{BlockLayout, DistMatrix, Mode};
use dbcsr::multiply::twofive::twofive_operands;
use dbcsr::multiply::{multiply, Algorithm, EngineOpts, MultiplyConfig};

const N: usize = 88; // 4 blocks of 22
const BLOCK: usize = 22;

fn main() {
    // ---- real numerics on 2x2x2 ------------------------------------------
    let parts = run_ranks(8, NetModel::aries(2), |world| {
        let g3 = Grid3D::new(world, 2, 2, 2);
        let (a, b) = twofive_operands(&g3, N, N, N, BLOCK, Mode::Real, 7, 8);
        let grid = Grid2D::new(g3.world.clone(), 2, 4);
        let cfg = MultiplyConfig {
            engine: EngineOpts {
                threads: 2,
                densify: true,
                ..Default::default()
            },
            algorithm: Algorithm::TwoFiveD { layers: 2 },
            ..Default::default()
        };
        let out = multiply(&grid, &a, &b, &cfg).unwrap();
        let mut dense = vec![0.0f32; N * N];
        out.c.add_into_dense(&mut dense);
        (dense, out.stats.comm_bytes, out.virtual_seconds)
    });
    let mut got = vec![0.0f32; N * N];
    for (part, _, _) in &parts {
        for (g, x) in got.iter_mut().zip(part.iter()) {
            *g += x;
        }
    }
    let ar = dense_reference(&BlockLayout::new(N, BLOCK), &BlockLayout::new(N, BLOCK), 7);
    let br = dense_reference(&BlockLayout::new(N, BLOCK), &BlockLayout::new(N, BLOCK), 8);
    let mut want = vec![0.0f32; N * N];
    smm_cpu::gemm_blocked(N, N, N, &ar, &br, &mut want);
    let max_err = got
        .iter()
        .zip(want.iter())
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!(
        "2.5D (2x2x2) {N}x{N}x{N} real multiply: max |C - C_ref| = {max_err:.2e} {}",
        if max_err < 2e-3 { "✓" } else { "✗" }
    );

    // ---- model-mode comm volume vs Cannon --------------------------------
    const DIM: usize = 1408;
    let mut t = Table::new(
        format!("per-rank comm per multiply, {DIM}² dense, 16 model ranks"),
        &["algorithm", "MiB/rank"],
    );
    let cannon: u64 = run_ranks(16, NetModel::aries(4), |world| {
        let grid = Grid2D::new(world, 4, 4);
        let coords = grid.coords();
        let a = DistMatrix::dense_cyclic(DIM, DIM, BLOCK, (4, 4), coords, Mode::Model, Fill::Zero);
        let b = a.clone();
        let cfg = MultiplyConfig {
            engine: EngineOpts {
                threads: 3,
                densify: true,
                ..Default::default()
            },
            algorithm: Algorithm::Cannon,
            ..Default::default()
        };
        multiply(&grid, &a, &b, &cfg).unwrap().stats.comm_bytes
    })
    .iter()
    .sum();
    t.row(vec![
        "Cannon 4x4".into(),
        format!("{:.1}", cannon as f64 / 16.0 / (1 << 20) as f64),
    ]);
    let twofive: u64 = run_ranks(16, NetModel::aries(4), |world| {
        let g3 = Grid3D::new(world, 2, 2, 4);
        let (a, b) = twofive_operands(&g3, DIM, DIM, DIM, BLOCK, Mode::Model, 1, 2);
        let grid = Grid2D::new(g3.world.clone(), 4, 4);
        let cfg = MultiplyConfig {
            engine: EngineOpts {
                threads: 3,
                densify: true,
                ..Default::default()
            },
            algorithm: Algorithm::TwoFiveD { layers: 4 },
            ..Default::default()
        };
        multiply(&grid, &a, &b, &cfg).unwrap().stats.comm_bytes
    })
    .iter()
    .sum();
    t.row(vec![
        "2.5D 2x2x4".into(),
        format!("{:.1}", twofive as f64 / 16.0 / (1 << 20) as f64),
    ]);
    t.print();
    println!(
        "2.5D c=4 moves {:.2}x less data per rank than Cannon",
        cannon as f64 / twofive as f64
    );
}
